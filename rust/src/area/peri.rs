//! Peripheral-circuit area model (paper §V-C, Table II).
//!
//! With the peri-under-array (PUA) structure, the peripherals sit under
//! the memory array; they fit as long as their area stays below the
//! plane footprint. Component unit areas are calibrated to Table II:
//!
//! | component      | mm² / plane | ratio |
//! |----------------|-------------|-------|
//! | HV-peri + cap  | 0.004210    | 21.62 % |
//! | LV-peri        | 0.004510    | 23.16 % |
//! | RPU + H-tree   | 0.000077    | 0.39 %  |
//!
//! LV-peri = BLS decoder, precharger, mux, ADC, page buffer, shift adder;
//! HV-peri = WL decoder (+ charge pump). RPUs were synthesized at 65 nm
//! and scaled to 7 nm; H-tree wiring uses the 7 nm M1 pitch.

use crate::bus::HTree;
use crate::circuit::{PlaneGeometry, TechParams};
use crate::config::{PlaneConfig, RpuConfig, SystemConfig};

/// Unit areas at the 7 nm LV node (m² per instance) and HV node.
#[derive(Debug, Clone, Copy)]
pub struct AreaUnits {
    /// One HV WL driver + level shifter (per stack layer).
    pub hv_wl_driver: f64,
    /// Charge-pump + HV routing overhead per plane (flat).
    pub hv_pump: f64,
    /// One 9-bit SAR ADC at 7 nm.
    pub adc: f64,
    /// One page-buffer latch (per bitline).
    pub pb_latch: f64,
    /// One precharge transistor + driver slice (per bitline).
    pub precharge: f64,
    /// One BLS driver (per row).
    pub bls_driver: f64,
    /// One 4:1 column mux slice (per active column).
    pub mux: f64,
    /// Shift-adder block per plane (flat).
    pub shift_adder: f64,
    /// One RPU at 65 nm (synthesis), scaled by `rpu_scale`.
    pub rpu_65nm: f64,
    /// Area scale factor 65 nm → 7 nm ((65/7)² ≈ 86×).
    pub rpu_scale: f64,
    /// M1 wire pitch at 7 nm (m) for the H-tree wiring.
    pub m1_pitch: f64,
    /// Parallel wires per H-tree link (bus width).
    pub htree_wires: usize,
}

impl Default for AreaUnits {
    fn default() -> Self {
        AreaUnits {
            hv_wl_driver: 31.0e-12, // 31 µm² — HV transistors are large
            hv_pump: 2.42e-10,      // 242 µm² flat
            adc: 4.0e-12,           // 4 µm² (9-bit SAR at 7 nm)
            pb_latch: 0.60e-12,
            precharge: 0.30e-12,
            bls_driver: 1.50e-12,
            mux: 0.50e-12,
            shift_adder: 2.35e-10, // 235 µm² flat
            rpu_65nm: 4.0e-9,      // 4000 µm² at 65 nm
            rpu_scale: (65.0f64 / 7.0) * (65.0 / 7.0),
            m1_pitch: 40e-9,
            htree_wires: 4, // narrow serialized links

        }
    }
}

/// Per-plane area breakdown (m²).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub hv_peri: f64,
    pub lv_peri: f64,
    pub rpu_htree: f64,
    /// Plane footprint (floorplan, staircase shared).
    pub plane: f64,
}

impl AreaBreakdown {
    pub fn total_peri(&self) -> f64 {
        self.hv_peri + self.lv_peri + self.rpu_htree
    }

    /// Ratio of each component to the plane footprint (Table II row 2).
    pub fn ratios(&self) -> (f64, f64, f64) {
        (self.hv_peri / self.plane, self.lv_peri / self.plane, self.rpu_htree / self.plane)
    }

    /// PUA feasibility: everything fits under the array when the summed
    /// peri ratio stays below 1 (paper: < 50 %).
    pub fn fits_under_array(&self) -> bool {
        self.total_peri() < self.plane
    }
}

/// The area model bound to a system.
pub struct AreaModel {
    pub units: AreaUnits,
    pub tech: TechParams,
}

impl AreaModel {
    pub fn new(tech: &TechParams) -> AreaModel {
        AreaModel { units: AreaUnits::default(), tech: tech.clone() }
    }

    /// Evaluate the per-plane breakdown for a system configuration.
    pub fn breakdown(&self, sys: &SystemConfig) -> AreaBreakdown {
        let p = &sys.plane;
        let u = &self.units;
        let geom = PlaneGeometry::of(p, &self.tech);

        // HV: one driver per stacked WL layer + the pump.
        let hv_peri = p.n_stack as f64 * u.hv_wl_driver + u.hv_pump;

        // LV read path: per-BL latches/prechargers, per-row BLS drivers,
        // ADCs + muxes on the active columns, plus the shift adder.
        let active_cols = p.n_col / sys.col_mux;
        let lv_peri = p.n_col as f64 * (u.pb_latch + u.precharge)
            + p.n_row as f64 * u.bls_driver
            + active_cols as f64 * (u.adc + u.mux)
            + u.shift_adder;

        // RPU (scaled from synthesis) + H-tree wiring, normalized per
        // plane: a die has planes-1 RPUs ≈ 1 per plane.
        let rpu = u.rpu_65nm / u.rpu_scale;
        let planes = sys.org.planes_per_die;
        let die_side = (planes as f64).sqrt() * (geom.area_floorplan(&self.tech)).sqrt();
        let tree = HTree::new(planes, crate::bus::Rpu::new(RpuConfig::default()), 1.0);
        let wire_len = tree.wire_length_units() * die_side;
        let wire_area = wire_len * u.m1_pitch * u.htree_wires as f64;
        let rpu_htree = rpu + wire_area / planes as f64;

        AreaBreakdown { hv_peri, lv_peri, rpu_htree, plane: geom.area_floorplan(&self.tech) }
    }

    /// Total array area of one die (mm²) — the §V-C "4.98 mm²" figure.
    pub fn die_array_mm2(&self, sys: &SystemConfig) -> f64 {
        let b = self.breakdown(sys);
        b.plane * sys.org.planes_per_die as f64 * 1e6
    }
}

/// Convenience: evaluate one plane standalone.
pub fn plane_floorplan_mm2(plane: &PlaneConfig, tech: &TechParams) -> f64 {
    PlaneGeometry::of(plane, tech).area_floorplan(tech) * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;

    fn breakdown() -> AreaBreakdown {
        AreaModel::new(&TechParams::default()).breakdown(&table1_system())
    }

    #[test]
    fn table2_hv_ratio() {
        let (hv, _, _) = breakdown().ratios();
        assert!((hv - 0.2162).abs() < 0.03, "HV ratio {hv:.4} vs paper 0.2162");
    }

    #[test]
    fn table2_lv_ratio() {
        let (_, lv, _) = breakdown().ratios();
        assert!((lv - 0.2316).abs() < 0.03, "LV ratio {lv:.4} vs paper 0.2316");
    }

    #[test]
    fn table2_rpu_htree_ratio() {
        let (_, _, r) = breakdown().ratios();
        assert!((r - 0.0039).abs() < 0.002, "RPU+H-tree ratio {r:.5} vs paper 0.0039");
    }

    #[test]
    fn peri_fits_under_array() {
        // Paper: peri + H-tree + RPUs < 50 % of the plane → PUA works.
        let b = breakdown();
        assert!(b.fits_under_array());
        assert!(b.total_peri() / b.plane < 0.50, "peri ratio {:.3}", b.total_peri() / b.plane);
    }

    #[test]
    fn die_array_near_4_98_mm2() {
        // Paper §V-C: 256 Size-A planes total 4.98 mm².
        let a = AreaModel::new(&TechParams::default()).die_array_mm2(&table1_system());
        assert!((a - 4.98).abs() / 4.98 < 0.03, "die array = {a:.3} mm²");
    }

    #[test]
    fn absolute_areas_match_table2() {
        let b = breakdown();
        let hv_mm2 = b.hv_peri * 1e6;
        let lv_mm2 = b.lv_peri * 1e6;
        let rpu_mm2 = b.rpu_htree * 1e6;
        assert!((hv_mm2 - 0.004210).abs() / 0.004210 < 0.10, "HV {hv_mm2:.6}");
        assert!((lv_mm2 - 0.004510).abs() / 0.004510 < 0.10, "LV {lv_mm2:.6}");
        assert!((rpu_mm2 - 0.000077).abs() / 0.000077 < 0.40, "RPU {rpu_mm2:.6}");
    }
}
