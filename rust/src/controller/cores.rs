//! ARM Cortex-A9 core model for the FP16 operations the flash PIM cannot
//! do in-array: LayerNorm and softmax (paper Fig. 10: "the cores in the
//! SSD controller execute the softmax and activation function in FP16;
//! the LN layer is also handled in SSD cores").

use crate::config::ControllerConfig;
use crate::sim::{ResourceBank, SimTime};

/// Per-element costs calibrated so OPT-30B TPOT lands near the paper's
/// ~7 ms with the Fig. 14b breakdown shape (softmax grows with context,
/// LN does not).
#[derive(Debug, Clone, Copy)]
pub struct CoreCosts {
    /// LayerNorm seconds per element (3 passes: mean, var, normalize —
    /// NEON FP16 at ~1 GHz).
    pub ln_per_elem: f64,
    /// Softmax seconds per element (exp via LUT + sum + divide).
    pub softmax_per_elem: f64,
    /// Fixed per-op dispatch overhead (interrupt + DMA setup).
    pub dispatch: f64,
}

impl Default for CoreCosts {
    fn default() -> Self {
        CoreCosts { ln_per_elem: 1.0e-9, softmax_per_elem: 4.0e-9, dispatch: 1.0e-6 }
    }
}

/// The controller's core bank.
pub struct ArmCores {
    pub cfg: ControllerConfig,
    pub costs: CoreCosts,
    bank: ResourceBank,
}

impl ArmCores {
    pub fn new(cfg: ControllerConfig) -> ArmCores {
        ArmCores { cfg, costs: CoreCosts::default(), bank: ResourceBank::new(cfg.arm_cores) }
    }

    /// LayerNorm over `d` elements: a single core handles one LN (the
    /// reduction is not worth splitting at d ≈ 10K).
    pub fn ln_time(&self, d: usize) -> SimTime {
        SimTime::from_secs(self.costs.dispatch + d as f64 * self.costs.ln_per_elem)
    }

    /// Softmax over `heads` rows of `l` scores each, spread across the
    /// core bank (heads are independent).
    pub fn softmax_time(&self, heads: usize, l: usize) -> SimTime {
        let rows_per_core = heads.div_ceil(self.cfg.arm_cores);
        SimTime::from_secs(
            self.costs.dispatch + (rows_per_core * l) as f64 * self.costs.softmax_per_elem,
        )
    }

    /// Schedule an LN on the bank at `at`; returns completion time.
    pub fn run_ln(&mut self, at: SimTime, d: usize) -> SimTime {
        let dur = self.ln_time(d);
        let (_, start) = self.bank.acquire(at, dur);
        start + dur
    }

    /// Schedule a softmax on the bank at `at` (modelled as occupying all
    /// cores for the balanced duration); returns completion time.
    pub fn run_softmax(&mut self, at: SimTime, heads: usize, l: usize) -> SimTime {
        let dur = self.softmax_time(heads, l);
        // Occupy every core for the duration (they all work on heads).
        let mut end = at;
        for _ in 0..self.cfg.arm_cores {
            let (_, start) = self.bank.acquire(at, dur);
            end = end.max(start + dur);
        }
        end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ControllerConfig;

    fn cores() -> ArmCores {
        ArmCores::new(ControllerConfig::default())
    }

    #[test]
    fn ln_independent_of_context_length() {
        // Fig. 14b: LN cost depends on d_m, not token counts.
        let c = cores();
        assert_eq!(c.ln_time(7168), c.ln_time(7168));
        assert!(c.ln_time(12288) > c.ln_time(4096));
    }

    #[test]
    fn softmax_grows_with_context() {
        // Fig. 14b: softmax is the component that scales with tokens.
        let c = cores();
        let t1 = c.softmax_time(56, 1024).secs();
        let t2 = c.softmax_time(56, 2048).secs();
        assert!(t2 > 1.5 * t1);
    }

    #[test]
    fn softmax_uses_all_cores() {
        let c = cores();
        // 56 heads over 4 cores: 14 rows per core.
        let t = c.softmax_time(56, 1024).secs();
        let serial = 56.0 * 1024.0 * c.costs.softmax_per_elem;
        assert!(t < serial / 3.0, "t={t}, serial={serial}");
    }

    #[test]
    fn bank_scheduling_advances() {
        let mut c = cores();
        let e1 = c.run_ln(SimTime::ZERO, 7168);
        let e2 = c.run_softmax(e1, 56, 1024);
        assert!(e2 > e1);
    }
}
