//! SSD-controller models (Table I): the ARM Cortex-A9 cores that execute
//! LayerNorm / softmax / activations in FP16, and the PCIe 5.0 ×4 host
//! link used for the initial KV-cache transfer.
//!
//! Both models are pure latency calculators over
//! [`ControllerConfig`](crate::config::ControllerConfig) — the serving
//! simulators call them to price every host-side step of a request, and
//! the per-token schedule ([`crate::llm::TokenSchedule`]) folds the ARM
//! cores into its LN/softmax terms.
//!
//! # Example
//!
//! Price a prompt's KV upload over the host link (the prefill term the
//! event-driven serving simulator charges before the first decode step):
//!
//! ```
//! use flashpim::config::ControllerConfig;
//! use flashpim::controller::PcieLink;
//! use flashpim::sim::SimTime;
//!
//! let cfg = ControllerConfig::default();
//! let link = PcieLink::new(&cfg);
//! let kv_bytes = 64.0 * 1024.0 * 1024.0; // 64 MiB of prompt KV
//! let t = link.transfer_time(kv_bytes);
//! // Never faster than the configured one-way latency, and a gen5 x4
//! // link moves 64 MiB in a handful of milliseconds.
//! assert!(t >= SimTime::from_ns(cfg.pcie_latency_ns));
//! assert!(t.secs() < 0.1);
//! ```

pub mod cores;
pub mod pcie;

pub use cores::ArmCores;
pub use pcie::PcieLink;
