//! SSD-controller models (Table I): the ARM Cortex-A9 cores that execute
//! LayerNorm / softmax / activations in FP16, and the PCIe 5.0 ×4 host
//! link used for the initial KV-cache transfer.

pub mod cores;
pub mod pcie;

pub use cores::ArmCores;
pub use pcie::PcieLink;
