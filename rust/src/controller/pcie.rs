//! PCIe 5.0 ×4 host link (Table I): carries the initial KV cache from GPU
//! DRAM to the flash device, and tokens/logits during serving.

use crate::config::ControllerConfig;
use crate::sim::{Resource, SimTime};

/// The host link.
#[derive(Debug, Clone)]
pub struct PcieLink {
    /// Effective bandwidth (bytes/s) after protocol overhead.
    pub bw: f64,
    /// One-way latency per transaction.
    pub latency: SimTime,
    timeline: Resource,
}

impl PcieLink {
    pub fn new(cfg: &ControllerConfig) -> PcieLink {
        PcieLink {
            // ~7.9 % encoding/TLP overhead on gen5.
            bw: cfg.pcie_bw() * 0.92,
            latency: SimTime::from_ns(cfg.pcie_latency_ns),
            timeline: Resource::new(),
        }
    }

    pub fn transfer_time(&self, bytes: f64) -> SimTime {
        self.latency + SimTime::from_secs(bytes / self.bw)
    }

    /// Schedule a transfer; returns completion.
    pub fn transfer(&mut self, at: SimTime, bytes: f64) -> SimTime {
        let dur = self.transfer_time(bytes);
        let start = self.timeline.acquire(at, dur);
        start + dur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ControllerConfig;

    #[test]
    fn gen5_x4_bandwidth() {
        let link = PcieLink::new(&ControllerConfig::default());
        // 4 lanes × ~3.94 GB/s × 0.92 ≈ 14.5 GB/s.
        assert!((14.0e9..15.0e9).contains(&link.bw), "bw = {}", link.bw);
    }

    #[test]
    fn latency_comes_from_config() {
        let mut cfg = ControllerConfig::default();
        assert_eq!(PcieLink::new(&cfg).latency, SimTime::from_ns(800.0));
        cfg.pcie_latency_ns = 1600.0;
        let link = PcieLink::new(&cfg);
        assert_eq!(link.latency, SimTime::from_ns(1600.0));
        // A zero-byte transfer is pure link latency.
        assert_eq!(link.transfer_time(0.0), SimTime::from_ns(1600.0));
    }

    #[test]
    fn small_transfers_latency_bound() {
        let link = PcieLink::new(&ControllerConfig::default());
        let t = link.transfer_time(64.0);
        assert!(t.secs() < 1e-6);
        assert!(t >= link.latency);
    }

    #[test]
    fn transfers_serialize() {
        let mut link = PcieLink::new(&ControllerConfig::default());
        let e1 = link.transfer(SimTime::ZERO, 1e9);
        let e2 = link.transfer(SimTime::ZERO, 1e9);
        assert!(e2 > e1);
        assert!(e2.secs() > 2.0 * 1e9 / link.bw);
    }
}
