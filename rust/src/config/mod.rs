//! Configuration system: a TOML-lite parser, typed configuration schema,
//! and presets mirroring the paper's Table I and the Size A / Size B plane
//! configurations — plus the serving workload-mix schema
//! ([`WorkloadSpec`]) and its built-in scenario presets
//! ([`workload_preset`]).

pub mod presets;
pub mod schema;
pub mod toml_lite;

pub use presets::{size_a_plane, size_b_plane, table1_system, workload_preset, WORKLOAD_PRESETS};
pub use schema::{
    BusTopology, CellKind, ControllerConfig, FlashOrgConfig, PlaneConfig, RpuConfig, SystemConfig,
    WorkloadClassSpec, WorkloadSpec,
};
