//! A minimal TOML subset parser (offline registry has no `serde`/`toml`).
//!
//! Supported: `[section]` headers, `key = value` with integer, float,
//! boolean, string, and flat arrays of those; `#` comments; blank lines.
//! This covers everything in `configs/*.toml`.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A scalar or flat-array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            other => bail!("expected int, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_int()?;
        if v < 0 {
            bail!("expected non-negative int, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(v) => Ok(*v),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(v) => Ok(v),
            other => bail!("expected string, got {other:?}"),
        }
    }
}

/// Parsed document: `section -> key -> value`. Keys before any `[section]`
/// land in the `""` section.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// Required lookup with a contextual error.
    pub fn require(&self, section: &str, key: &str) -> Result<&Value> {
        self.get(section, key).ok_or_else(|| anyhow!("missing [{section}] {key}"))
    }

    /// Optional integer with default.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(section, key) {
            Some(v) => v.as_int(),
            None => Ok(default),
        }
    }

    /// Optional float with default.
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            Some(v) => v.as_float(),
            None => Ok(default),
        }
    }

    /// Optional string with default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }
}

/// Parse a TOML-lite document from a string.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.sections.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: malformed section header {line:?}", lineno + 1))?
                .trim()
                .to_string();
            doc.sections.entry(name.clone()).or_default();
            current = name;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`, got {line:?}", lineno + 1))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: value for key {key:?}", lineno + 1))?;
        doc.sections.get_mut(&current).expect("section exists").insert(key, val);
    }
    Ok(doc)
}

/// Parse a file.
pub fn parse_file(path: &Path) -> Result<Doc> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array {s:?}"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            items.push(parse_value(p)?);
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    let clean = s.replace('_', "");
    if let Ok(v) = clean.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = clean.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    // Arrays are flat, so a comma split with quote-awareness suffices.
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
            # top comment
            global_key = 7
            [plane]
            n_row = 256
            n_col = 2_048
            pitch = 40.5       # nm
            enabled = true
            name = "size-a"
            dims = [256, 2048, 128]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "global_key").unwrap().as_int().unwrap(), 7);
        assert_eq!(doc.get("plane", "n_col").unwrap().as_int().unwrap(), 2048);
        assert!((doc.get("plane", "pitch").unwrap().as_float().unwrap() - 40.5).abs() < 1e-12);
        assert!(doc.get("plane", "enabled").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("plane", "name").unwrap().as_str().unwrap(), "size-a");
        match doc.get("plane", "dims").unwrap() {
            Value::Array(xs) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn error_on_missing_equals() {
        assert!(parse("[s]\njust-a-token").is_err());
    }

    #[test]
    fn int_float_promotion() {
        let doc = parse("x = 3").unwrap();
        assert!((doc.get("", "x").unwrap().as_float().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn defaults_api() {
        let doc = parse("[a]\nx = 1").unwrap();
        assert_eq!(doc.int_or("a", "x", 9).unwrap(), 1);
        assert_eq!(doc.int_or("a", "y", 9).unwrap(), 9);
        assert_eq!(doc.str_or("a", "name", "dflt").unwrap(), "dflt");
    }
}
