//! Typed system configuration — the paper's Table I plus the plane-size
//! parameters explored in Section III — and the serving-workload schema
//! ([`WorkloadSpec`]) behind `serve-sim --workload`.

use super::toml_lite::{Doc, Value};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Cell technology of a die region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// Single-level cell: 1 bit/cell, fast program, high endurance. Used
    /// for the KV-cache region (non-PIM dies).
    Slc,
    /// Quad-level cell: 4 bits/cell. Used for the PIM weight region.
    Qlc,
}

impl CellKind {
    pub fn bits_per_cell(self) -> usize {
        match self {
            CellKind::Slc => 1,
            CellKind::Qlc => 4,
        }
    }
}

/// Geometry of one 3D NAND plane: `N_row × N_col × N_stack` (paper §III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlaneConfig {
    /// Number of rows (BLS lines). The plane width W is proportional to this.
    pub n_row: usize,
    /// Number of bitlines (columns); page size = n_col cells.
    pub n_col: usize,
    /// Number of stacked wordline layers.
    pub n_stack: usize,
    /// Cell kind of this plane.
    pub cell: CellKind,
}

impl PlaneConfig {
    pub const fn new(n_row: usize, n_col: usize, n_stack: usize, cell: CellKind) -> PlaneConfig {
        PlaneConfig { n_row, n_col, n_stack, cell }
    }

    /// Total cell count.
    pub fn cells(&self) -> usize {
        self.n_row * self.n_col * self.n_stack
    }

    /// Total bit capacity.
    pub fn capacity_bits(&self) -> usize {
        self.cells() * self.cell.bits_per_cell()
    }

    /// Validate physical plausibility bounds used by the DSE sweep.
    pub fn validate(&self) -> Result<()> {
        if !self.n_row.is_power_of_two() || !self.n_col.is_power_of_two() || !self.n_stack.is_power_of_two() {
            bail!("plane dims must be powers of two: {self:?}");
        }
        if self.n_row < 16 || self.n_row > 16_384 {
            bail!("n_row out of range: {}", self.n_row);
        }
        if self.n_col < 128 || self.n_col > 65_536 {
            bail!("n_col out of range: {}", self.n_col);
        }
        if self.n_stack < 8 || self.n_stack > 1_024 {
            bail!("n_stack out of range: {}", self.n_stack);
        }
        Ok(())
    }
}

/// Intra-die interconnect topology (paper §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusTopology {
    /// Conventional single shared bus; one plane transfers at a time and
    /// all PIM outputs travel to the die port for accumulation.
    Shared,
    /// Binary H-tree with an RPU at each internal node; outputs are
    /// accumulated on the way to the die port.
    HTree,
}

/// Reconfigurable processing unit parameters (Table I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RpuConfig {
    /// Clock frequency in Hz (paper: 250 MHz, chosen to match bus BW).
    pub freq_hz: f64,
    /// INT16 multipliers per RPU.
    pub int16_mults: usize,
    /// INT32 adders per RPU.
    pub int32_adders: usize,
}

impl Default for RpuConfig {
    fn default() -> Self {
        RpuConfig { freq_hz: 250e6, int16_mults: 8, int32_adders: 9 }
    }
}

/// Flash organization: the channel/way/die/plane hierarchy (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashOrgConfig {
    pub channels: usize,
    pub ways_per_channel: usize,
    pub dies_per_way: usize,
    pub planes_per_die: usize,
    /// Dies per way reserved as non-PIM SLC (KV cache); the rest are
    /// PIM-enabled QLC (weights). Paper: 8 dies = 2 SLC + 6 QLC.
    pub slc_dies_per_way: usize,
}

impl FlashOrgConfig {
    pub fn total_dies(&self) -> usize {
        self.channels * self.ways_per_channel * self.dies_per_way
    }

    pub fn total_planes(&self) -> usize {
        self.total_dies() * self.planes_per_die
    }

    pub fn qlc_dies_per_way(&self) -> usize {
        self.dies_per_way - self.slc_dies_per_way
    }

    pub fn validate(&self) -> Result<()> {
        if self.slc_dies_per_way >= self.dies_per_way {
            bail!("SLC dies ({}) must leave at least one QLC die of {}", self.slc_dies_per_way, self.dies_per_way);
        }
        if !self.planes_per_die.is_power_of_two() {
            bail!("planes per die must be a power of two for the H-tree");
        }
        Ok(())
    }
}

/// SSD controller parameters (Table I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// ARM cores available for LN/softmax/activation.
    pub arm_cores: usize,
    /// Core clock in Hz.
    pub arm_freq_hz: f64,
    /// PCIe lanes (gen 5).
    pub pcie_lanes: usize,
    /// PCIe per-lane bandwidth, bytes/s (gen5 ≈ 3.938 GB/s/lane).
    pub pcie_lane_bw: f64,
    /// PCIe one-way latency per transaction, nanoseconds (gen5 switch +
    /// root-complex traversal ≈ 800 ns).
    pub pcie_latency_ns: f64,
    /// Flash channel bus bandwidth, bytes/s (Table I: 2 GB/s = 1000 MT/s × 8-bit... per channel).
    pub channel_bus_bw: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            arm_cores: 4,
            arm_freq_hz: 1.0e9,
            pcie_lanes: 4,
            pcie_lane_bw: 3.938e9,
            pcie_latency_ns: 800.0,
            channel_bus_bw: 2.0e9,
        }
    }
}

impl ControllerConfig {
    /// Host-link bandwidth in bytes/s.
    pub fn pcie_bw(&self) -> f64 {
        self.pcie_lanes as f64 * self.pcie_lane_bw
    }
}

/// The full system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// Human-readable preset name.
    pub name: String,
    /// PIM (QLC) plane geometry.
    pub plane: PlaneConfig,
    pub org: FlashOrgConfig,
    pub bus: BusTopology,
    pub rpu: RpuConfig,
    pub ctrl: ControllerConfig,
    /// Input (activation) bit width for PIM bit-serial operation.
    pub input_bits: usize,
    /// Weight bit width (stored across `weight_bits / bits_per_cell` cells).
    pub weight_bits: usize,
    /// Max cells accumulated on one BL per PIM op (reliability limit; paper: 256).
    pub max_cells_per_bl: usize,
    /// Column multiplexing ratio in the PIM read path (paper: 4:1).
    pub col_mux: usize,
}

impl SystemConfig {
    /// Rows of an sMVM unit tile: `max_cells_per_bl / cells_per_weight`
    /// (paper: u = 128 with 256-cell limit and 2 QLC cells per 8-bit weight).
    pub fn tile_rows(&self) -> usize {
        let cells_per_weight = self.weight_bits / self.plane.cell.bits_per_cell();
        self.max_cells_per_bl / cells_per_weight.max(1)
    }

    /// Output columns of an sMVM unit tile: `n_col / col_mux / cells_per_weight`
    /// BLs are shared pairwise per 8-bit weight, but mux groups activate
    /// `n_col / col_mux` BLs concurrently — the paper's unit tile is
    /// `u × (N_col/4)` weights, i.e. `n_col/col_mux` weight columns.
    pub fn tile_cols(&self) -> usize {
        self.plane.n_col / self.col_mux
    }

    pub fn validate(&self) -> Result<()> {
        self.plane.validate()?;
        self.org.validate()?;
        if self.input_bits == 0 || self.input_bits > 16 {
            bail!("input_bits out of range");
        }
        if self.weight_bits % self.plane.cell.bits_per_cell() != 0 {
            bail!("weight bits must be a multiple of bits/cell");
        }
        Ok(())
    }

    /// Load from a TOML-lite file; missing keys fall back to the Table I preset.
    pub fn from_file(path: &Path) -> Result<SystemConfig> {
        let doc = super::toml_lite::parse_file(path)?;
        Self::from_doc(&doc)
    }

    /// Build from a parsed document.
    pub fn from_doc(doc: &Doc) -> Result<SystemConfig> {
        let base = super::presets::table1_system();
        let plane = PlaneConfig {
            n_row: doc.int_or("plane", "n_row", base.plane.n_row as i64)? as usize,
            n_col: doc.int_or("plane", "n_col", base.plane.n_col as i64)? as usize,
            n_stack: doc.int_or("plane", "n_stack", base.plane.n_stack as i64)? as usize,
            cell: match doc.str_or("plane", "cell", "qlc")?.as_str() {
                "slc" => CellKind::Slc,
                "qlc" => CellKind::Qlc,
                other => bail!("unknown cell kind {other:?}"),
            },
        };
        let org = FlashOrgConfig {
            channels: doc.int_or("org", "channels", base.org.channels as i64)? as usize,
            ways_per_channel: doc.int_or("org", "ways_per_channel", base.org.ways_per_channel as i64)? as usize,
            dies_per_way: doc.int_or("org", "dies_per_way", base.org.dies_per_way as i64)? as usize,
            planes_per_die: doc.int_or("org", "planes_per_die", base.org.planes_per_die as i64)? as usize,
            slc_dies_per_way: doc.int_or("org", "slc_dies_per_way", base.org.slc_dies_per_way as i64)? as usize,
        };
        let bus = match doc.str_or("bus", "topology", "htree")?.as_str() {
            "shared" => BusTopology::Shared,
            "htree" => BusTopology::HTree,
            other => bail!("unknown bus topology {other:?}"),
        };
        let rpu = RpuConfig {
            freq_hz: doc.float_or("rpu", "freq_hz", base.rpu.freq_hz)?,
            int16_mults: doc.int_or("rpu", "int16_mults", base.rpu.int16_mults as i64)? as usize,
            int32_adders: doc.int_or("rpu", "int32_adders", base.rpu.int32_adders as i64)? as usize,
        };
        let ctrl = ControllerConfig {
            arm_cores: doc.int_or("controller", "arm_cores", base.ctrl.arm_cores as i64)? as usize,
            arm_freq_hz: doc.float_or("controller", "arm_freq_hz", base.ctrl.arm_freq_hz)?,
            pcie_lanes: doc.int_or("controller", "pcie_lanes", base.ctrl.pcie_lanes as i64)? as usize,
            pcie_lane_bw: doc.float_or("controller", "pcie_lane_bw", base.ctrl.pcie_lane_bw)?,
            pcie_latency_ns: doc
                .float_or("controller", "pcie_latency_ns", base.ctrl.pcie_latency_ns)?,
            channel_bus_bw: doc.float_or("controller", "channel_bus_bw", base.ctrl.channel_bus_bw)?,
        };
        let cfg = SystemConfig {
            name: doc.str_or("", "name", &base.name)?,
            plane,
            org,
            bus,
            rpu,
            ctrl,
            input_bits: doc.int_or("pim", "input_bits", base.input_bits as i64)? as usize,
            weight_bits: doc.int_or("pim", "weight_bits", base.weight_bits as i64)? as usize,
            max_cells_per_bl: doc.int_or("pim", "max_cells_per_bl", base.max_cells_per_bl as i64)? as usize,
            col_mux: doc.int_or("pim", "col_mux", base.col_mux as i64)? as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One request class of a serving workload mix: its weight in the arrival
/// stream, prompt/output length ranges, follow-up probability, and
/// per-class SLO targets. This is the plain-numbers *schema* type the
/// TOML files and presets speak; `coordinator::workload` converts it into
/// the runtime `WorkloadClass`.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadClassSpec {
    pub name: String,
    /// Relative arrival-rate share; normalized across the mix's classes.
    pub share: f64,
    /// Prompt-length range `[lo, hi]`, inclusive, in tokens.
    pub input: (usize, usize),
    /// Output-length range `[lo, hi]`, inclusive, in tokens.
    pub output: (usize, usize),
    /// Probability that an arrival of this class is a follow-up turn of
    /// one of the class's own finished sessions.
    pub followup: f64,
    /// Time-to-first-token SLO target, seconds (`f64::INFINITY` = none).
    pub ttft_slo: f64,
    /// Time-per-output-token SLO target, seconds (`f64::INFINITY` = none).
    pub tpot_slo: f64,
}

/// Workload names are embedded verbatim in TOML section headers and
/// quoted strings by [`WorkloadSpec::to_toml`]; restricting them to
/// `[A-Za-z0-9_-]` keeps the documented parse/render round-trip exact
/// (no `#`, `"`, `]`, or newline escaping cases to get wrong).
fn valid_workload_name(name: &str) -> bool {
    !name.is_empty() && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
}

impl WorkloadClassSpec {
    pub fn validate(&self) -> Result<()> {
        if !valid_workload_name(&self.name) {
            bail!(
                "workload class name {:?} must be non-empty and use only [A-Za-z0-9_-]",
                self.name
            );
        }
        if !(self.share.is_finite() && self.share > 0.0) {
            bail!("class {:?}: share must be positive and finite, got {}", self.name, self.share);
        }
        for (which, (lo, hi)) in [("input", self.input), ("output", self.output)] {
            if lo < 1 || hi < lo {
                bail!("class {:?}: {which} range needs 1 <= lo <= hi, got [{lo}, {hi}]", self.name);
            }
        }
        if !(0.0..=1.0).contains(&self.followup) {
            bail!("class {:?}: followup must be in [0, 1], got {}", self.name, self.followup);
        }
        for (which, slo) in [("ttft_slo", self.ttft_slo), ("tpot_slo", self.tpot_slo)] {
            // Infinity is the explicit "no target" value, so only NaN and
            // non-positive targets are rejected.
            if slo.is_nan() || slo <= 0.0 {
                bail!("class {:?}: {which} must be positive, got {slo}", self.name);
            }
        }
        Ok(())
    }
}

/// A named, weighted set of [`WorkloadClassSpec`]s — the TOML face of a
/// serving scenario (see `docs/WORKLOADS.md`). Files look like:
///
/// ```toml
/// name = "support-desk"
///
/// [class.chat]
/// share = 0.7
/// input = [128, 256]
/// output = [32, 64]
/// followup = 0.3
/// ttft_slo = 0.15   # seconds
/// tpot_slo = 0.004  # seconds per output token
///
/// [class.reports]
/// share = 0.3
/// input = [1024, 1792]
/// output = [64, 128]
/// ```
///
/// Classes are indexed in section-name order (alphabetical — [`Doc`]
/// stores sections in a `BTreeMap`), which pins the class ⇄ RNG-stream
/// association for a given file: the same file always samples the same
/// trace from the same seed.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    pub classes: Vec<WorkloadClassSpec>,
}

impl WorkloadSpec {
    pub fn validate(&self) -> Result<()> {
        if !valid_workload_name(&self.name) {
            bail!("workload name {:?} must be non-empty and use only [A-Za-z0-9_-]", self.name);
        }
        if self.classes.is_empty() {
            bail!("workload {:?} needs at least one [class.<name>] section", self.name);
        }
        for c in &self.classes {
            c.validate()?;
        }
        let mut names: Vec<&str> = self.classes.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            bail!("duplicate workload class names in {:?}", self.name);
        }
        Ok(())
    }

    /// Load from a TOML-lite file.
    pub fn from_file(path: &Path) -> Result<WorkloadSpec> {
        let doc = super::toml_lite::parse_file(path)?;
        Self::from_doc(&doc).with_context(|| format!("workload file {}", path.display()))
    }

    /// Build from a parsed document: a top-level `name` plus one
    /// `[class.<name>]` section per class.
    pub fn from_doc(doc: &Doc) -> Result<WorkloadSpec> {
        let name = doc.str_or("", "name", "custom")?;
        let mut classes = Vec::new();
        for section in doc.sections.keys() {
            let Some(class_name) = section.strip_prefix("class.") else {
                continue;
            };
            let range = |key: &str| -> Result<(usize, usize)> {
                match doc.get(section, key) {
                    Some(Value::Array(xs)) if xs.len() == 2 => {
                        Ok((xs[0].as_usize()?, xs[1].as_usize()?))
                    }
                    Some(other) => {
                        bail!("[{section}] {key} must be a two-element array, got {other:?}")
                    }
                    None => bail!("[{section}] is missing `{key} = [lo, hi]`"),
                }
            };
            classes.push(WorkloadClassSpec {
                name: class_name.trim().to_string(),
                share: doc.float_or(section, "share", 1.0)?,
                input: range("input")?,
                output: range("output")?,
                followup: doc.float_or(section, "followup", 0.0)?,
                ttft_slo: doc.float_or(section, "ttft_slo", f64::INFINITY)?,
                tpot_slo: doc.float_or(section, "tpot_slo", f64::INFINITY)?,
            });
        }
        let spec = WorkloadSpec { name, classes };
        spec.validate()?;
        Ok(spec)
    }

    /// Render back to TOML-lite. `from_doc(parse(to_toml()))` reproduces
    /// the spec exactly when class names are already in ascending order
    /// (parsing normalizes section order); `f64` `Display` round-trips
    /// bit-exactly, including `inf` for "no target".
    pub fn to_toml(&self) -> String {
        let mut out = format!("name = \"{}\"\n", self.name);
        for c in &self.classes {
            out.push_str(&format!(
                "\n[class.{}]\nshare = {}\ninput = [{}, {}]\noutput = [{}, {}]\n\
                 followup = {}\nttft_slo = {}\ntpot_slo = {}\n",
                c.name,
                c.share,
                c.input.0,
                c.input.1,
                c.output.0,
                c.output.1,
                c.followup,
                c.ttft_slo,
                c.tpot_slo,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn table1_is_valid() {
        presets::table1_system().validate().unwrap();
    }

    #[test]
    fn tile_shape_matches_paper() {
        // Paper §IV-B: u = 128 rows, unit tile u × (N_col/4) = 128 × 512.
        let cfg = presets::table1_system();
        assert_eq!(cfg.tile_rows(), 128);
        assert_eq!(cfg.tile_cols(), 512);
    }

    #[test]
    fn capacity_of_size_a() {
        let p = presets::size_a_plane();
        // 256 × 2048 × 128 QLC cells × 4 bits.
        assert_eq!(p.capacity_bits(), 256 * 2048 * 128 * 4);
    }

    #[test]
    fn invalid_plane_rejected() {
        let p = PlaneConfig::new(300, 2048, 128, CellKind::Qlc); // 300 not pow2
        assert!(p.validate().is_err());
    }

    #[test]
    fn from_doc_overrides() {
        let doc = crate::config::toml_lite::parse(
            "[plane]\nn_col = 1024\nn_stack = 64\n[bus]\ntopology = \"shared\"",
        )
        .unwrap();
        let cfg = SystemConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.plane.n_col, 1024);
        assert_eq!(cfg.bus, BusTopology::Shared);
        assert_eq!(cfg.org.channels, 8); // inherited from Table I
    }

    #[test]
    fn workload_spec_parses_and_round_trips() {
        let text = "\
name = \"demo\"

[class.chat]
share = 0.7
input = [128, 256]
output = [32, 64]
followup = 0.3
ttft_slo = 0.15
tpot_slo = 0.004

[class.reports]
share = 0.3
input = [1024, 1792]
output = [64, 128]
";
        let doc = crate::config::toml_lite::parse(text).unwrap();
        let spec = WorkloadSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.classes.len(), 2);
        assert_eq!(spec.classes[0].name, "chat");
        assert_eq!(spec.classes[0].input, (128, 256));
        assert_eq!(spec.classes[0].ttft_slo, 0.15);
        // Omitted keys fall back: share 1.0 default not used here, but
        // followup and the SLO targets were omitted for `reports`.
        assert_eq!(spec.classes[1].followup, 0.0);
        assert_eq!(spec.classes[1].ttft_slo, f64::INFINITY);
        // Exact round-trip through to_toml.
        let reparsed =
            WorkloadSpec::from_doc(&crate::config::toml_lite::parse(&spec.to_toml()).unwrap())
                .unwrap();
        assert_eq!(spec, reparsed);
    }

    #[test]
    fn workload_spec_rejects_bad_input() {
        let parse =
            |s: &str| WorkloadSpec::from_doc(&crate::config::toml_lite::parse(s).unwrap());
        // No classes at all.
        assert!(parse("name = \"empty\"").is_err());
        // Malformed range.
        assert!(parse("[class.a]\ninput = [8]\noutput = [1, 2]").is_err());
        assert!(parse("[class.a]\ninput = [9, 8]\noutput = [1, 2]").is_err());
        // Bad share / followup / SLO.
        assert!(parse("[class.a]\ninput = [1, 2]\noutput = [1, 2]\nshare = 0").is_err());
        assert!(parse("[class.a]\ninput = [1, 2]\noutput = [1, 2]\nfollowup = 1.5").is_err());
        assert!(parse("[class.a]\ninput = [1, 2]\noutput = [1, 2]\nttft_slo = -1").is_err());
        // Names land verbatim in section headers / quoted strings, so the
        // TOML-hostile characters are rejected up front.
        for bad in ["a b", "a\"b", "a]b", ""] {
            let spec = WorkloadSpec {
                name: "ok".into(),
                classes: vec![WorkloadClassSpec { name: bad.to_string(), ..presets::chat_class() }],
            };
            assert!(spec.validate().is_err(), "class name {bad:?} must be rejected");
        }
        // `#` would truncate the header at the comment stripper.
        let hash = WorkloadSpec {
            name: "a#b".into(),
            classes: vec![presets::chat_class()],
        };
        assert!(hash.validate().is_err());
        // Duplicate names on a hand-built spec.
        let dup = WorkloadSpec {
            name: "dup".into(),
            classes: vec![presets::chat_class(), presets::chat_class()],
        };
        assert!(dup.validate().is_err());
    }

    #[test]
    fn pcie_latency_defaults_and_overrides() {
        // The 800 ns one-way latency lives in the schema (it used to be
        // hardcoded inside `controller::pcie`), so presets and TOML files
        // can change it.
        assert_eq!(ControllerConfig::default().pcie_latency_ns, 800.0);
        let doc =
            crate::config::toml_lite::parse("[controller]\npcie_latency_ns = 1600.0").unwrap();
        let cfg = SystemConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.ctrl.pcie_latency_ns, 1600.0);
        assert_eq!(cfg.ctrl.pcie_lanes, 4); // the rest inherits Table I
    }
}
