//! Typed system configuration — the paper's Table I plus the plane-size
//! parameters explored in Section III.

use super::toml_lite::Doc;
use anyhow::{bail, Result};
use std::path::Path;

/// Cell technology of a die region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// Single-level cell: 1 bit/cell, fast program, high endurance. Used
    /// for the KV-cache region (non-PIM dies).
    Slc,
    /// Quad-level cell: 4 bits/cell. Used for the PIM weight region.
    Qlc,
}

impl CellKind {
    pub fn bits_per_cell(self) -> usize {
        match self {
            CellKind::Slc => 1,
            CellKind::Qlc => 4,
        }
    }
}

/// Geometry of one 3D NAND plane: `N_row × N_col × N_stack` (paper §III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlaneConfig {
    /// Number of rows (BLS lines). The plane width W is proportional to this.
    pub n_row: usize,
    /// Number of bitlines (columns); page size = n_col cells.
    pub n_col: usize,
    /// Number of stacked wordline layers.
    pub n_stack: usize,
    /// Cell kind of this plane.
    pub cell: CellKind,
}

impl PlaneConfig {
    pub const fn new(n_row: usize, n_col: usize, n_stack: usize, cell: CellKind) -> PlaneConfig {
        PlaneConfig { n_row, n_col, n_stack, cell }
    }

    /// Total cell count.
    pub fn cells(&self) -> usize {
        self.n_row * self.n_col * self.n_stack
    }

    /// Total bit capacity.
    pub fn capacity_bits(&self) -> usize {
        self.cells() * self.cell.bits_per_cell()
    }

    /// Validate physical plausibility bounds used by the DSE sweep.
    pub fn validate(&self) -> Result<()> {
        if !self.n_row.is_power_of_two() || !self.n_col.is_power_of_two() || !self.n_stack.is_power_of_two() {
            bail!("plane dims must be powers of two: {self:?}");
        }
        if self.n_row < 16 || self.n_row > 16_384 {
            bail!("n_row out of range: {}", self.n_row);
        }
        if self.n_col < 128 || self.n_col > 65_536 {
            bail!("n_col out of range: {}", self.n_col);
        }
        if self.n_stack < 8 || self.n_stack > 1_024 {
            bail!("n_stack out of range: {}", self.n_stack);
        }
        Ok(())
    }
}

/// Intra-die interconnect topology (paper §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusTopology {
    /// Conventional single shared bus; one plane transfers at a time and
    /// all PIM outputs travel to the die port for accumulation.
    Shared,
    /// Binary H-tree with an RPU at each internal node; outputs are
    /// accumulated on the way to the die port.
    HTree,
}

/// Reconfigurable processing unit parameters (Table I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RpuConfig {
    /// Clock frequency in Hz (paper: 250 MHz, chosen to match bus BW).
    pub freq_hz: f64,
    /// INT16 multipliers per RPU.
    pub int16_mults: usize,
    /// INT32 adders per RPU.
    pub int32_adders: usize,
}

impl Default for RpuConfig {
    fn default() -> Self {
        RpuConfig { freq_hz: 250e6, int16_mults: 8, int32_adders: 9 }
    }
}

/// Flash organization: the channel/way/die/plane hierarchy (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashOrgConfig {
    pub channels: usize,
    pub ways_per_channel: usize,
    pub dies_per_way: usize,
    pub planes_per_die: usize,
    /// Dies per way reserved as non-PIM SLC (KV cache); the rest are
    /// PIM-enabled QLC (weights). Paper: 8 dies = 2 SLC + 6 QLC.
    pub slc_dies_per_way: usize,
}

impl FlashOrgConfig {
    pub fn total_dies(&self) -> usize {
        self.channels * self.ways_per_channel * self.dies_per_way
    }

    pub fn total_planes(&self) -> usize {
        self.total_dies() * self.planes_per_die
    }

    pub fn qlc_dies_per_way(&self) -> usize {
        self.dies_per_way - self.slc_dies_per_way
    }

    pub fn validate(&self) -> Result<()> {
        if self.slc_dies_per_way >= self.dies_per_way {
            bail!("SLC dies ({}) must leave at least one QLC die of {}", self.slc_dies_per_way, self.dies_per_way);
        }
        if !self.planes_per_die.is_power_of_two() {
            bail!("planes per die must be a power of two for the H-tree");
        }
        Ok(())
    }
}

/// SSD controller parameters (Table I).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// ARM cores available for LN/softmax/activation.
    pub arm_cores: usize,
    /// Core clock in Hz.
    pub arm_freq_hz: f64,
    /// PCIe lanes (gen 5).
    pub pcie_lanes: usize,
    /// PCIe per-lane bandwidth, bytes/s (gen5 ≈ 3.938 GB/s/lane).
    pub pcie_lane_bw: f64,
    /// PCIe one-way latency per transaction, nanoseconds (gen5 switch +
    /// root-complex traversal ≈ 800 ns).
    pub pcie_latency_ns: f64,
    /// Flash channel bus bandwidth, bytes/s (Table I: 2 GB/s = 1000 MT/s × 8-bit... per channel).
    pub channel_bus_bw: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            arm_cores: 4,
            arm_freq_hz: 1.0e9,
            pcie_lanes: 4,
            pcie_lane_bw: 3.938e9,
            pcie_latency_ns: 800.0,
            channel_bus_bw: 2.0e9,
        }
    }
}

impl ControllerConfig {
    /// Host-link bandwidth in bytes/s.
    pub fn pcie_bw(&self) -> f64 {
        self.pcie_lanes as f64 * self.pcie_lane_bw
    }
}

/// The full system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// Human-readable preset name.
    pub name: String,
    /// PIM (QLC) plane geometry.
    pub plane: PlaneConfig,
    pub org: FlashOrgConfig,
    pub bus: BusTopology,
    pub rpu: RpuConfig,
    pub ctrl: ControllerConfig,
    /// Input (activation) bit width for PIM bit-serial operation.
    pub input_bits: usize,
    /// Weight bit width (stored across `weight_bits / bits_per_cell` cells).
    pub weight_bits: usize,
    /// Max cells accumulated on one BL per PIM op (reliability limit; paper: 256).
    pub max_cells_per_bl: usize,
    /// Column multiplexing ratio in the PIM read path (paper: 4:1).
    pub col_mux: usize,
}

impl SystemConfig {
    /// Rows of an sMVM unit tile: `max_cells_per_bl / cells_per_weight`
    /// (paper: u = 128 with 256-cell limit and 2 QLC cells per 8-bit weight).
    pub fn tile_rows(&self) -> usize {
        let cells_per_weight = self.weight_bits / self.plane.cell.bits_per_cell();
        self.max_cells_per_bl / cells_per_weight.max(1)
    }

    /// Output columns of an sMVM unit tile: `n_col / col_mux / cells_per_weight`
    /// BLs are shared pairwise per 8-bit weight, but mux groups activate
    /// `n_col / col_mux` BLs concurrently — the paper's unit tile is
    /// `u × (N_col/4)` weights, i.e. `n_col/col_mux` weight columns.
    pub fn tile_cols(&self) -> usize {
        self.plane.n_col / self.col_mux
    }

    pub fn validate(&self) -> Result<()> {
        self.plane.validate()?;
        self.org.validate()?;
        if self.input_bits == 0 || self.input_bits > 16 {
            bail!("input_bits out of range");
        }
        if self.weight_bits % self.plane.cell.bits_per_cell() != 0 {
            bail!("weight bits must be a multiple of bits/cell");
        }
        Ok(())
    }

    /// Load from a TOML-lite file; missing keys fall back to the Table I preset.
    pub fn from_file(path: &Path) -> Result<SystemConfig> {
        let doc = super::toml_lite::parse_file(path)?;
        Self::from_doc(&doc)
    }

    /// Build from a parsed document.
    pub fn from_doc(doc: &Doc) -> Result<SystemConfig> {
        let base = super::presets::table1_system();
        let plane = PlaneConfig {
            n_row: doc.int_or("plane", "n_row", base.plane.n_row as i64)? as usize,
            n_col: doc.int_or("plane", "n_col", base.plane.n_col as i64)? as usize,
            n_stack: doc.int_or("plane", "n_stack", base.plane.n_stack as i64)? as usize,
            cell: match doc.str_or("plane", "cell", "qlc")?.as_str() {
                "slc" => CellKind::Slc,
                "qlc" => CellKind::Qlc,
                other => bail!("unknown cell kind {other:?}"),
            },
        };
        let org = FlashOrgConfig {
            channels: doc.int_or("org", "channels", base.org.channels as i64)? as usize,
            ways_per_channel: doc.int_or("org", "ways_per_channel", base.org.ways_per_channel as i64)? as usize,
            dies_per_way: doc.int_or("org", "dies_per_way", base.org.dies_per_way as i64)? as usize,
            planes_per_die: doc.int_or("org", "planes_per_die", base.org.planes_per_die as i64)? as usize,
            slc_dies_per_way: doc.int_or("org", "slc_dies_per_way", base.org.slc_dies_per_way as i64)? as usize,
        };
        let bus = match doc.str_or("bus", "topology", "htree")?.as_str() {
            "shared" => BusTopology::Shared,
            "htree" => BusTopology::HTree,
            other => bail!("unknown bus topology {other:?}"),
        };
        let rpu = RpuConfig {
            freq_hz: doc.float_or("rpu", "freq_hz", base.rpu.freq_hz)?,
            int16_mults: doc.int_or("rpu", "int16_mults", base.rpu.int16_mults as i64)? as usize,
            int32_adders: doc.int_or("rpu", "int32_adders", base.rpu.int32_adders as i64)? as usize,
        };
        let ctrl = ControllerConfig {
            arm_cores: doc.int_or("controller", "arm_cores", base.ctrl.arm_cores as i64)? as usize,
            arm_freq_hz: doc.float_or("controller", "arm_freq_hz", base.ctrl.arm_freq_hz)?,
            pcie_lanes: doc.int_or("controller", "pcie_lanes", base.ctrl.pcie_lanes as i64)? as usize,
            pcie_lane_bw: doc.float_or("controller", "pcie_lane_bw", base.ctrl.pcie_lane_bw)?,
            pcie_latency_ns: doc
                .float_or("controller", "pcie_latency_ns", base.ctrl.pcie_latency_ns)?,
            channel_bus_bw: doc.float_or("controller", "channel_bus_bw", base.ctrl.channel_bus_bw)?,
        };
        let cfg = SystemConfig {
            name: doc.str_or("", "name", &base.name)?,
            plane,
            org,
            bus,
            rpu,
            ctrl,
            input_bits: doc.int_or("pim", "input_bits", base.input_bits as i64)? as usize,
            weight_bits: doc.int_or("pim", "weight_bits", base.weight_bits as i64)? as usize,
            max_cells_per_bl: doc.int_or("pim", "max_cells_per_bl", base.max_cells_per_bl as i64)? as usize,
            col_mux: doc.int_or("pim", "col_mux", base.col_mux as i64)? as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn table1_is_valid() {
        presets::table1_system().validate().unwrap();
    }

    #[test]
    fn tile_shape_matches_paper() {
        // Paper §IV-B: u = 128 rows, unit tile u × (N_col/4) = 128 × 512.
        let cfg = presets::table1_system();
        assert_eq!(cfg.tile_rows(), 128);
        assert_eq!(cfg.tile_cols(), 512);
    }

    #[test]
    fn capacity_of_size_a() {
        let p = presets::size_a_plane();
        // 256 × 2048 × 128 QLC cells × 4 bits.
        assert_eq!(p.capacity_bits(), 256 * 2048 * 128 * 4);
    }

    #[test]
    fn invalid_plane_rejected() {
        let p = PlaneConfig::new(300, 2048, 128, CellKind::Qlc); // 300 not pow2
        assert!(p.validate().is_err());
    }

    #[test]
    fn from_doc_overrides() {
        let doc = crate::config::toml_lite::parse(
            "[plane]\nn_col = 1024\nn_stack = 64\n[bus]\ntopology = \"shared\"",
        )
        .unwrap();
        let cfg = SystemConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.plane.n_col, 1024);
        assert_eq!(cfg.bus, BusTopology::Shared);
        assert_eq!(cfg.org.channels, 8); // inherited from Table I
    }

    #[test]
    fn pcie_latency_defaults_and_overrides() {
        // The 800 ns one-way latency lives in the schema (it used to be
        // hardcoded inside `controller::pcie`), so presets and TOML files
        // can change it.
        assert_eq!(ControllerConfig::default().pcie_latency_ns, 800.0);
        let doc =
            crate::config::toml_lite::parse("[controller]\npcie_latency_ns = 1600.0").unwrap();
        let cfg = SystemConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.ctrl.pcie_latency_ns, 1600.0);
        assert_eq!(cfg.ctrl.pcie_lanes, 4); // the rest inherits Table I
    }
}
