//! Named presets: the paper's Table I system, the Size A / Size B plane
//! configurations from §III-B/C, and the built-in serving workload
//! classes/mixes behind `serve-sim --workload` (see `docs/WORKLOADS.md`).

use super::schema::*;

/// Size A: `256 × 2048 × 128` QLC — the plane selected in §III-B for
/// maximum cell density at ~2 µs PIM latency.
pub fn size_a_plane() -> PlaneConfig {
    PlaneConfig::new(256, 2048, 128, CellKind::Qlc)
}

/// Size B: `256 × 1024 × 64` QLC — the smaller, faster, half-density
/// alternative of Fig. 9b.
pub fn size_b_plane() -> PlaneConfig {
    PlaneConfig::new(256, 1024, 64, CellKind::Qlc)
}

/// A conventional (non-PIM-optimized) plane: large page, many blocks —
/// the baseline of Fig. 5 with 20–50 µs read latency.
pub fn conventional_plane() -> PlaneConfig {
    // 16 KiB page (128 Kb = 16K BLs), 1400 blocks × 4 rows = 4096 rows
    // (mid-range of "700–2800 blocks/plane, 4 rows/block"), 128 stacks.
    PlaneConfig::new(4096, 16_384, 128, CellKind::Qlc)
}

/// The full Table I system.
///
/// * Controller: 4× ARM Cortex-A9, PCIe 5.0 ×4
/// * Flash: 8 channels, 4 ways, 8 dies/way (2 SLC + 6 QLC), 256 planes/die
/// * Page 256 B, 4 BLS/block, 64 blocks, 128 stacks; bus 2 GB/s
/// * RPU: 250 MHz, 8× INT16 mult, 9× INT32 add
pub fn table1_system() -> SystemConfig {
    SystemConfig {
        name: "table1".to_string(),
        plane: size_a_plane(),
        org: FlashOrgConfig {
            channels: 8,
            ways_per_channel: 4,
            dies_per_way: 8,
            planes_per_die: 256,
            slc_dies_per_way: 2,
        },
        bus: BusTopology::HTree,
        rpu: RpuConfig::default(),
        ctrl: ControllerConfig::default(),
        input_bits: 8,
        weight_bits: 8,
        max_cells_per_bl: 256,
        col_mux: 4,
    }
}

/// Table I system with the shared-bus topology (Fig. 9a baseline).
pub fn table1_shared_bus() -> SystemConfig {
    SystemConfig { bus: BusTopology::Shared, name: "table1-shared".into(), ..table1_system() }
}

/// Table I system with Size B planes (Fig. 9b comparison).
pub fn table1_size_b() -> SystemConfig {
    SystemConfig { plane: size_b_plane(), name: "table1-size-b".into(), ..table1_system() }
}

/// Interactive chat turns: short prompts, short outputs, frequent
/// follow-ups, tight TTFT. Also the single definition behind the default
/// single-class traffic of `TrafficConfig::default_for` — the legacy
/// path and the workload path share these constants.
pub fn chat_class() -> WorkloadClassSpec {
    WorkloadClassSpec {
        name: "chat".to_string(),
        share: 1.0,
        input: (128, 256),
        output: (32, 64),
        followup: 0.3,
        ttft_slo: 0.150,
        tpot_slo: 0.004,
    }
}

/// Long-context summarization: 1K+-token prompts (the paper's §I
/// GPU-side workload, here offloaded whole), short outputs, a loose TTFT
/// budget that absorbs the large initial KV write.
pub fn summarize_long_class() -> WorkloadClassSpec {
    WorkloadClassSpec {
        name: "summarize-long".to_string(),
        share: 1.0,
        input: (1024, 1792),
        output: (64, 128),
        followup: 0.1,
        ttft_slo: 2.0,
        tpot_slo: 0.006,
    }
}

/// Agentic tool-use chains: tiny prompts, short outputs, and a high
/// follow-up probability — one session issues a burst of dependent turns,
/// each wanting a very fast first token.
pub fn agentic_class() -> WorkloadClassSpec {
    WorkloadClassSpec {
        name: "agentic".to_string(),
        share: 1.0,
        input: (32, 96),
        output: (16, 48),
        followup: 0.85,
        ttft_slo: 0.100,
        tpot_slo: 0.004,
    }
}

/// Offline batch generation: long prompts, long outputs, no interactive
/// deadline to speak of — the class exists to soak spare capacity without
/// starving the interactive classes.
pub fn batch_class() -> WorkloadClassSpec {
    WorkloadClassSpec {
        name: "batch".to_string(),
        share: 1.0,
        input: (512, 1024),
        output: (256, 512),
        followup: 0.0,
        ttft_slo: 30.0,
        tpot_slo: 0.020,
    }
}

/// Built-in mix names accepted by `serve-sim --workload`, ascending.
pub const WORKLOAD_PRESETS: &[&str] =
    &["agentic-burst", "batch-offline", "chat", "summarize-long"];

/// Built-in workload mixes. Class lists are kept in ascending name order
/// so a mix round-trips exactly through its TOML rendering
/// ([`WorkloadSpec::to_toml`] / [`WorkloadSpec::from_doc`]).
pub fn workload_preset(name: &str) -> Option<WorkloadSpec> {
    let with_share = |mut c: WorkloadClassSpec, share: f64| {
        c.share = share;
        c
    };
    let spec = |classes: Vec<WorkloadClassSpec>| WorkloadSpec { name: name.to_string(), classes };
    match name {
        // Pure interactive chat — the single-class baseline scenario.
        "chat" => Some(spec(vec![chat_class()])),
        // Adversarial blend: interactive turns arriving behind 1K+-token
        // prefills. The scenario the SLO-aware scheduler exists for.
        "summarize-long" => Some(spec(vec![
            with_share(chat_class(), 0.6),
            with_share(summarize_long_class(), 0.4),
        ])),
        // Bursty dependent chains over a chat background; exercises KV
        // affinity (follow-ups pin to the device holding the session KV).
        "agentic-burst" => Some(spec(vec![
            with_share(agentic_class(), 0.55),
            with_share(chat_class(), 0.45),
        ])),
        // Throughput filler under an interactive foreground.
        "batch-offline" => Some(spec(vec![
            with_share(batch_class(), 0.3),
            with_share(chat_class(), 0.7),
        ])),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        table1_system().validate().unwrap();
        table1_shared_bus().validate().unwrap();
        table1_size_b().validate().unwrap();
        conventional_plane().validate().unwrap();
    }

    #[test]
    fn workload_presets_validate_and_round_trip() {
        for name in WORKLOAD_PRESETS {
            let spec = workload_preset(name).unwrap_or_else(|| panic!("missing preset {name}"));
            spec.validate().unwrap_or_else(|e| panic!("preset {name}: {e}"));
            assert_eq!(spec.name, *name);
            // Class names ascend, so the TOML rendering round-trips exactly.
            assert!(spec.classes.windows(2).all(|w| w[0].name < w[1].name), "{name} unsorted");
            let doc = crate::config::toml_lite::parse(&spec.to_toml()).unwrap();
            assert_eq!(WorkloadSpec::from_doc(&doc).unwrap(), spec);
        }
        assert!(workload_preset("bogus").is_none());
    }

    #[test]
    fn default_traffic_and_chat_class_share_one_definition() {
        // The `chat` class is THE definition of the default traffic shape;
        // `TrafficConfig::default_for` delegates to it (asserted on the
        // coordinator side), so these constants only live here.
        let c = chat_class();
        assert_eq!((c.input, c.output), ((128, 256), (32, 64)));
        assert_eq!(c.followup, 0.3);
    }

    #[test]
    fn org_counts_match_table1() {
        let s = table1_system();
        assert_eq!(s.org.total_dies(), 8 * 4 * 8);
        assert_eq!(s.org.total_planes(), 8 * 4 * 8 * 256);
        assert_eq!(s.org.qlc_dies_per_way(), 6);
    }

    #[test]
    fn size_b_is_quarter_capacity_of_a() {
        assert_eq!(size_a_plane().capacity_bits(), 4 * size_b_plane().capacity_bits());
    }
}
