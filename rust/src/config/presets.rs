//! Named presets: the paper's Table I system and the Size A / Size B plane
//! configurations from §III-B/C.

use super::schema::*;

/// Size A: `256 × 2048 × 128` QLC — the plane selected in §III-B for
/// maximum cell density at ~2 µs PIM latency.
pub fn size_a_plane() -> PlaneConfig {
    PlaneConfig::new(256, 2048, 128, CellKind::Qlc)
}

/// Size B: `256 × 1024 × 64` QLC — the smaller, faster, half-density
/// alternative of Fig. 9b.
pub fn size_b_plane() -> PlaneConfig {
    PlaneConfig::new(256, 1024, 64, CellKind::Qlc)
}

/// A conventional (non-PIM-optimized) plane: large page, many blocks —
/// the baseline of Fig. 5 with 20–50 µs read latency.
pub fn conventional_plane() -> PlaneConfig {
    // 16 KiB page (128 Kb = 16K BLs), 1400 blocks × 4 rows = 4096 rows
    // (mid-range of "700–2800 blocks/plane, 4 rows/block"), 128 stacks.
    PlaneConfig::new(4096, 16_384, 128, CellKind::Qlc)
}

/// The full Table I system.
///
/// * Controller: 4× ARM Cortex-A9, PCIe 5.0 ×4
/// * Flash: 8 channels, 4 ways, 8 dies/way (2 SLC + 6 QLC), 256 planes/die
/// * Page 256 B, 4 BLS/block, 64 blocks, 128 stacks; bus 2 GB/s
/// * RPU: 250 MHz, 8× INT16 mult, 9× INT32 add
pub fn table1_system() -> SystemConfig {
    SystemConfig {
        name: "table1".to_string(),
        plane: size_a_plane(),
        org: FlashOrgConfig {
            channels: 8,
            ways_per_channel: 4,
            dies_per_way: 8,
            planes_per_die: 256,
            slc_dies_per_way: 2,
        },
        bus: BusTopology::HTree,
        rpu: RpuConfig::default(),
        ctrl: ControllerConfig::default(),
        input_bits: 8,
        weight_bits: 8,
        max_cells_per_bl: 256,
        col_mux: 4,
    }
}

/// Table I system with the shared-bus topology (Fig. 9a baseline).
pub fn table1_shared_bus() -> SystemConfig {
    SystemConfig { bus: BusTopology::Shared, name: "table1-shared".into(), ..table1_system() }
}

/// Table I system with Size B planes (Fig. 9b comparison).
pub fn table1_size_b() -> SystemConfig {
    SystemConfig { plane: size_b_plane(), name: "table1-size-b".into(), ..table1_system() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        table1_system().validate().unwrap();
        table1_shared_bus().validate().unwrap();
        table1_size_b().validate().unwrap();
        conventional_plane().validate().unwrap();
    }

    #[test]
    fn org_counts_match_table1() {
        let s = table1_system();
        assert_eq!(s.org.total_dies(), 8 * 4 * 8);
        assert_eq!(s.org.total_planes(), 8 * 4 * 8 * 256);
        assert_eq!(s.org.qlc_dies_per_way(), 6);
    }

    #[test]
    fn size_b_is_quarter_capacity_of_a() {
        assert_eq!(size_a_plane().capacity_bits(), 4 * size_b_plane().capacity_bits());
    }
}
