//! Flash operation timing for a configured system: bridges the circuit
//! model (per-plane latencies) and the cell model (program) into the
//! quantities the pipeline simulators consume.

use super::cell::CellParams;
use crate::circuit::{PlaneLatency, TechParams};
use crate::config::{CellKind, PlaneConfig, SystemConfig};
use crate::sim::SimTime;

/// Pre-computed operation latencies for one plane geometry.
#[derive(Debug, Clone)]
pub struct NandTiming {
    /// PIM dot-product op, full `input_bits` bit-serial pass (Eq. 3).
    pub t_pim: SimTime,
    /// Regular page read of the PIM (QLC) plane (Eq. 1).
    pub t_read_qlc: SimTime,
    /// Regular page read of an SLC plane with the same geometry.
    pub t_read_slc: SimTime,
    /// SLC page program (KV-cache append path).
    pub t_program_slc: SimTime,
    /// QLC page program (weight load path, offline).
    pub t_program_qlc: SimTime,
    /// Raw breakdown for reporting.
    pub breakdown: PlaneLatency,
}

impl NandTiming {
    /// Derive timing for `plane` under `tech`, with the system's input
    /// bit-width.
    pub fn derive(plane: &PlaneConfig, tech: &TechParams, input_bits: usize) -> NandTiming {
        let lat = PlaneLatency::of(plane, tech);
        let slc_plane = PlaneConfig { cell: CellKind::Slc, ..*plane };
        let lat_slc = PlaneLatency::of(&slc_plane, tech);
        NandTiming {
            t_pim: SimTime::from_secs(lat.t_pim(input_bits)),
            t_read_qlc: SimTime::from_secs(lat.t_read(CellKind::Qlc, tech)),
            t_read_slc: SimTime::from_secs(lat_slc.t_read(CellKind::Slc, tech)),
            t_program_slc: SimTime::from_secs(CellParams::of(CellKind::Slc).t_program),
            t_program_qlc: SimTime::from_secs(CellParams::of(CellKind::Qlc).t_program),
            breakdown: lat,
        }
    }

    /// Derive from a full system config.
    pub fn of_system(sys: &SystemConfig, tech: &TechParams) -> NandTiming {
        NandTiming::derive(&sys.plane, tech, sys.input_bits)
    }

    /// Page size in bytes for a plane (one WL × BLS row of cells).
    /// Table I: page size = 256 B for the Size A plane (2048 cells × 4 bit
    /// per QLC cell / 8 bits per byte... the *PIM page* is what one BLS
    /// activation exposes to the bitlines).
    pub fn page_bytes(plane: &PlaneConfig) -> usize {
        plane.n_col * plane.cell.bits_per_cell() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{size_a_plane, table1_system};

    #[test]
    fn size_a_page_is_1kib_qlc() {
        // 2048 QLC cells × 4 bits = 1 KiB of raw data per page.
        assert_eq!(NandTiming::page_bytes(&size_a_plane()), 1024);
    }

    #[test]
    fn pim_op_near_2us() {
        let sys = table1_system();
        let t = NandTiming::of_system(&sys, &TechParams::default());
        let s = t.t_pim.secs();
        assert!((1.7e-6..=2.3e-6).contains(&s), "t_pim = {s}");
    }

    #[test]
    fn slc_read_faster_than_qlc() {
        let sys = table1_system();
        let t = NandTiming::of_system(&sys, &TechParams::default());
        assert!(t.t_read_slc < t.t_read_qlc);
    }

    #[test]
    fn program_far_slower_than_read() {
        let sys = table1_system();
        let t = NandTiming::of_system(&sys, &TechParams::default());
        assert!(t.t_program_slc.secs() > 10.0 * t.t_read_slc.secs());
    }
}
