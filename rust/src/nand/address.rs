//! Addressing across the flash hierarchy: channel / way / die / plane.

use crate::config::FlashOrgConfig;

/// Address of a die.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DieAddr {
    pub channel: usize,
    pub way: usize,
    pub die: usize,
}

impl DieAddr {
    /// Linear index in channel-major order.
    pub fn linear(&self, org: &FlashOrgConfig) -> usize {
        (self.channel * org.ways_per_channel + self.way) * org.dies_per_way + self.die
    }

    pub fn from_linear(idx: usize, org: &FlashOrgConfig) -> DieAddr {
        let die = idx % org.dies_per_way;
        let rest = idx / org.dies_per_way;
        let way = rest % org.ways_per_channel;
        let channel = rest / org.ways_per_channel;
        DieAddr { channel, way, die }
    }
}

/// Address of a plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlaneAddr {
    pub die: DieAddr,
    pub plane: usize,
}

impl PlaneAddr {
    pub fn new(channel: usize, way: usize, die: usize, plane: usize) -> PlaneAddr {
        PlaneAddr { die: DieAddr { channel, way, die }, plane }
    }

    /// Linear index in channel-major order.
    pub fn linear(&self, org: &FlashOrgConfig) -> usize {
        self.die.linear(org) * org.planes_per_die + self.plane
    }

    pub fn from_linear(idx: usize, org: &FlashOrgConfig) -> PlaneAddr {
        let plane = idx % org.planes_per_die;
        let die = DieAddr::from_linear(idx / org.planes_per_die, org);
        PlaneAddr { die, plane }
    }
}

/// Iterate all die addresses in linear order.
pub fn all_dies(org: &FlashOrgConfig) -> impl Iterator<Item = DieAddr> + '_ {
    (0..org.total_dies()).map(move |i| DieAddr::from_linear(i, org))
}

/// Iterate all plane addresses in linear order.
pub fn all_planes(org: &FlashOrgConfig) -> impl Iterator<Item = PlaneAddr> + '_ {
    (0..org.total_planes()).map(move |i| PlaneAddr::from_linear(i, org))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;

    #[test]
    fn linear_roundtrip_dies() {
        let org = table1_system().org;
        for i in 0..org.total_dies() {
            let a = DieAddr::from_linear(i, &org);
            assert_eq!(a.linear(&org), i);
            assert!(a.channel < org.channels);
            assert!(a.way < org.ways_per_channel);
            assert!(a.die < org.dies_per_way);
        }
    }

    #[test]
    fn linear_roundtrip_planes() {
        let org = table1_system().org;
        for i in (0..org.total_planes()).step_by(97) {
            let a = PlaneAddr::from_linear(i, &org);
            assert_eq!(a.linear(&org), i);
        }
    }

    #[test]
    fn iteration_counts() {
        let org = table1_system().org;
        assert_eq!(all_dies(&org).count(), 256);
        assert_eq!(all_planes(&org).count(), 256 * 256);
    }
}
