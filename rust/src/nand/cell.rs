//! Cell-technology parameters: program latency and endurance for SLC and
//! QLC (paper §IV-B: SLC programming is 19× faster than QLC [16]; SLC
//! endures ~10K P/E cycles, extendable ~50× by relaxing retention to
//! 3 days via WARM-style management [17]).

use crate::config::CellKind;

/// Per-cell-kind program/endurance parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellParams {
    pub kind: CellKind,
    /// Page program latency (s).
    pub t_program: f64,
    /// Baseline program/erase endurance (cycles).
    pub pe_cycles: u64,
    /// Endurance multiplier when retention is relaxed to days
    /// (write-hot data like the KV cache).
    pub retention_relax_factor: f64,
}

impl CellParams {
    pub fn of(kind: CellKind) -> CellParams {
        match kind {
            // SLC: fast single-shot program, high endurance.
            CellKind::Slc => CellParams {
                kind,
                t_program: 100e-6,
                pe_cycles: 10_000,
                retention_relax_factor: 50.0,
            },
            // QLC: multi-pass ISPP programming — 19× slower (paper [16]).
            CellKind::Qlc => CellParams {
                kind,
                t_program: 1_900e-6,
                pe_cycles: 1_000,
                retention_relax_factor: 50.0,
            },
        }
    }

    /// Effective endurance with retention-relaxed management.
    pub fn relaxed_pe_cycles(&self) -> f64 {
        self.pe_cycles as f64 * self.retention_relax_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_programs_19x_faster_than_qlc() {
        let slc = CellParams::of(CellKind::Slc);
        let qlc = CellParams::of(CellKind::Qlc);
        let ratio = qlc.t_program / slc.t_program;
        assert!((ratio - 19.0).abs() < 0.5, "program ratio = {ratio}");
    }

    #[test]
    fn slc_relaxed_endurance_500k() {
        // 10K × 50 = 500K effective cycles (paper §IV-B lifetime argument).
        let slc = CellParams::of(CellKind::Slc);
        assert!((slc.relaxed_pe_cycles() - 500_000.0).abs() < 1.0);
    }

    #[test]
    fn qlc_endures_less_than_slc() {
        assert!(CellParams::of(CellKind::Qlc).pe_cycles < CellParams::of(CellKind::Slc).pe_cycles);
    }
}
