//! Per-plane state: the page buffer, resident weight tiles (QLC PIM
//! region) or KV pages (SLC region), and the busy timeline.

use crate::config::PlaneConfig;
use crate::sim::{Resource, SimTime};

/// Identifier of a weight tile resident in a plane (set by the sMVM
/// mapper): which operation and which (row-tile, col-tile) block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileId {
    pub op: u32,
    pub row_tile: u32,
    pub col_tile: u32,
}

/// Mutable simulation state of one plane.
#[derive(Debug)]
pub struct PlaneState {
    pub config: PlaneConfig,
    /// Exclusive-use timeline (a plane does one op at a time).
    pub busy: Resource,
    /// Contents of the page buffer, if loaded (byte payload id + length).
    page_buffer: Option<(u64, usize)>,
    /// Weight tiles programmed into this plane (QLC PIM region).
    tiles: Vec<TileId>,
    /// Cumulative program count (endurance accounting, SLC region).
    programs: u64,
}

impl PlaneState {
    pub fn new(config: PlaneConfig) -> PlaneState {
        PlaneState { config, busy: Resource::new(), page_buffer: None, tiles: Vec::new(), programs: 0 }
    }

    /// Load a page into the page buffer (completes a read).
    pub fn latch_page(&mut self, payload_id: u64, len: usize) {
        self.page_buffer = Some((payload_id, len));
    }

    pub fn page_buffer(&self) -> Option<(u64, usize)> {
        self.page_buffer
    }

    /// Record a programmed tile (weight load).
    pub fn program_tile(&mut self, tile: TileId) {
        self.tiles.push(tile);
        self.programs += 1;
    }

    pub fn tiles(&self) -> &[TileId] {
        &self.tiles
    }

    pub fn programs(&self) -> u64 {
        self.programs
    }

    /// Record a KV page program (no tile bookkeeping).
    pub fn program_page(&mut self) {
        self.programs += 1;
    }

    /// Schedule an exclusive op at `at` lasting `dur`; returns start time.
    pub fn schedule(&mut self, at: SimTime, dur: SimTime) -> SimTime {
        self.busy.acquire(at, dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::size_a_plane;

    #[test]
    fn page_buffer_latch() {
        let mut p = PlaneState::new(size_a_plane());
        assert!(p.page_buffer().is_none());
        p.latch_page(42, 1024);
        assert_eq!(p.page_buffer(), Some((42, 1024)));
    }

    #[test]
    fn ops_serialize_on_plane() {
        let mut p = PlaneState::new(size_a_plane());
        let s1 = p.schedule(SimTime(0), SimTime(100));
        let s2 = p.schedule(SimTime(10), SimTime(100));
        assert_eq!(s1, SimTime(0));
        assert_eq!(s2, SimTime(100));
    }

    #[test]
    fn tile_bookkeeping() {
        let mut p = PlaneState::new(size_a_plane());
        p.program_tile(TileId { op: 0, row_tile: 1, col_tile: 2 });
        p.program_tile(TileId { op: 0, row_tile: 1, col_tile: 3 });
        assert_eq!(p.tiles().len(), 2);
        assert_eq!(p.programs(), 2);
    }
}
