//! The 3D NAND flash hierarchy (paper Fig. 2): channels → ways (packages)
//! → dies → planes, with SLC/QLC die partitioning (Fig. 10d), addressing,
//! and operation timing derived from the circuit model.

pub mod address;
pub mod cell;
pub mod organization;
pub mod plane;
pub mod timing;

pub use address::{DieAddr, PlaneAddr};
pub use cell::CellParams;
pub use organization::FlashOrganization;
pub use plane::PlaneState;
pub use timing::NandTiming;
