//! The assembled flash device: all dies/planes with the QLC–SLC hybrid
//! partition of Fig. 10d. Dies `0..slc_dies_per_way` of each way are the
//! non-PIM SLC region (KV cache); the rest are PIM-enabled QLC (weights).

use super::address::{DieAddr, PlaneAddr};
use super::plane::PlaneState;
use crate::config::{CellKind, FlashOrgConfig, PlaneConfig, SystemConfig};

/// The whole flash device's plane states, indexed by linear plane address.
pub struct FlashOrganization {
    pub org: FlashOrgConfig,
    pub qlc_plane: PlaneConfig,
    pub slc_plane: PlaneConfig,
    planes: Vec<PlaneState>,
}

impl FlashOrganization {
    pub fn new(sys: &SystemConfig) -> FlashOrganization {
        let org = sys.org;
        let qlc_plane = sys.plane;
        let slc_plane = PlaneConfig { cell: CellKind::Slc, ..sys.plane };
        let planes = (0..org.total_planes())
            .map(|i| {
                let addr = PlaneAddr::from_linear(i, &org);
                let cfg = if Self::die_is_slc(&org, addr.die) { slc_plane } else { qlc_plane };
                PlaneState::new(cfg)
            })
            .collect();
        FlashOrganization { org, qlc_plane, slc_plane, planes }
    }

    /// Whether a die belongs to the SLC (KV cache) region.
    pub fn die_is_slc(org: &FlashOrgConfig, die: DieAddr) -> bool {
        die.die < org.slc_dies_per_way
    }

    pub fn is_slc(&self, addr: PlaneAddr) -> bool {
        Self::die_is_slc(&self.org, addr.die)
    }

    pub fn plane(&self, addr: PlaneAddr) -> &PlaneState {
        &self.planes[addr.linear(&self.org)]
    }

    pub fn plane_mut(&mut self, addr: PlaneAddr) -> &mut PlaneState {
        &mut self.planes[addr.linear(&self.org)]
    }

    /// All QLC (PIM) die addresses.
    pub fn qlc_dies(&self) -> Vec<DieAddr> {
        super::address::all_dies(&self.org).filter(|d| !Self::die_is_slc(&self.org, *d)).collect()
    }

    /// All SLC (KV) die addresses.
    pub fn slc_dies(&self) -> Vec<DieAddr> {
        super::address::all_dies(&self.org).filter(|d| Self::die_is_slc(&self.org, *d)).collect()
    }

    /// Total QLC capacity in bytes (weight storage).
    pub fn qlc_capacity_bytes(&self) -> u64 {
        self.qlc_dies().len() as u64
            * self.org.planes_per_die as u64
            * (self.qlc_plane.capacity_bits() as u64 / 8)
    }

    /// Total SLC capacity in bytes (KV-cache storage).
    pub fn slc_capacity_bytes(&self) -> u64 {
        self.slc_dies().len() as u64
            * self.org.planes_per_die as u64
            * (self.slc_plane.capacity_bits() as u64 / 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::table1_system;

    #[test]
    fn table1_partition_counts() {
        let f = FlashOrganization::new(&table1_system());
        // 8 ch × 4 way × (2 SLC + 6 QLC) dies.
        assert_eq!(f.slc_dies().len(), 8 * 4 * 2);
        assert_eq!(f.qlc_dies().len(), 8 * 4 * 6);
    }

    #[test]
    fn slc_planes_are_slc_cells() {
        let f = FlashOrganization::new(&table1_system());
        let slc_addr = PlaneAddr::new(0, 0, 0, 0); // die 0 < slc_dies_per_way=2
        let qlc_addr = PlaneAddr::new(0, 0, 7, 0);
        assert!(f.is_slc(slc_addr));
        assert!(!f.is_slc(qlc_addr));
        assert_eq!(f.plane(slc_addr).config.cell, CellKind::Slc);
        assert_eq!(f.plane(qlc_addr).config.cell, CellKind::Qlc);
    }

    #[test]
    fn capacities() {
        let f = FlashOrganization::new(&table1_system());
        // QLC: 192 dies × 256 planes × 256 Mb / 8 = 192 × 8 GiB... per-plane
        // 2048·128·256·4 bits = 32 MiB.
        let per_plane = (256usize * 2048 * 128 * 4 / 8) as u64;
        assert_eq!(f.qlc_capacity_bytes(), 192 * 256 * per_plane);
        // SLC plane stores 1/4 the bits of a QLC plane.
        assert_eq!(f.slc_capacity_bytes(), 64 * 256 * per_plane / 4);
        // Sanity: the device actually fits OPT-175B in W8A8 (175 GB).
        assert!(f.qlc_capacity_bytes() > 175_000_000_000);
    }

    #[test]
    fn slc_kv_region_32gib_order() {
        // Paper §IV-B sizes the KV region at 32 GiB for the lifetime
        // estimate; the Table-I SLC region is of that order.
        let f = FlashOrganization::new(&table1_system());
        let gib = f.slc_capacity_bytes() as f64 / (1u64 << 30) as f64;
        assert!(gib >= 32.0 && gib <= 1024.0, "SLC region = {gib} GiB");
    }
}
