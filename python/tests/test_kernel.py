"""Kernel-vs-oracle correctness: the CORE L1 signal.

The Pallas kernel must match the pure-jnp PIM oracle bit-exactly for
every shape/value combination, and the oracle itself must stay within
the documented ADC error bound of the exact integer matmul.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.pim_mvm import pim_mvm


def rand_int8(rng, shape):
    return rng.integers(-128, 128, size=shape).astype(np.int32)


@pytest.mark.parametrize(
    "m,n",
    [(1, 1), (7, 3), (128, 512), (128, 513), (129, 64), (256, 1024), (300, 100), (64, 512)],
)
def test_kernel_matches_ref_shapes(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    x = rand_int8(rng, (m,))
    w = rand_int8(rng, (m, n))
    got = np.asarray(pim_mvm(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.pim_mvm_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_hypothesis(m, n, seed):
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m,))
    w = rand_int8(rng, (m, n))
    got = np.asarray(pim_mvm(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.pim_mvm_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=256),
    n=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_ref_within_adc_error_bound(m, n, seed):
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m,))
    w = rand_int8(rng, (m, n))
    approx = np.asarray(ref.pim_mvm_ref(jnp.asarray(x), jnp.asarray(w)))
    exact = np.asarray(ref.exact_mvm(jnp.asarray(x), jnp.asarray(w)))
    bound = ref.adc_error_bound(m)
    assert np.max(np.abs(approx - exact)) <= bound


def test_ref_exact_when_adc_ideal():
    # adc_step=1 and sums below the 9-bit range -> no quantization at all.
    rng = np.random.default_rng(0)
    m, n = 64, 32
    x = rng.integers(0, 4, size=(m,)).astype(np.int32)  # small positive
    w = rng.integers(-8, 8, size=(m, n)).astype(np.int32)
    approx = np.asarray(ref.pim_mvm_ref(jnp.asarray(x), jnp.asarray(w), adc_step=1))
    exact = np.asarray(ref.exact_mvm(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(approx, exact)


def test_extreme_values():
    # -128/127 corners exercise the two's-complement paths.
    x = jnp.asarray([-128, 127, -1, 0, 1] * 26)[:128]
    w = jnp.asarray(np.tile(np.asarray([[-128, 127, -1, 1]], dtype=np.int32), (128, 1)))
    got = np.asarray(pim_mvm(x, w))
    want = np.asarray(ref.pim_mvm_ref(x, w))
    np.testing.assert_array_equal(got, want)


def test_zero_input_gives_zero():
    x = jnp.zeros((128,), jnp.int32)
    w = jnp.asarray(np.random.default_rng(1).integers(-128, 128, (128, 16)), dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(pim_mvm(x, w)), np.zeros(16, np.int32))


def test_adc_transfer_function():
    s = jnp.asarray([0, 1, 3, 4, 5, 2047, 2048, 100000])
    q = np.asarray(ref.adc(s))
    # floor to step 4, clip to 511 codes
    assert list(q) == [0, 0, 0, 4, 4, 2044, 2044, 2044]


def test_block_boundary_consistency():
    # Same input evaluated with different block sizes must agree.
    rng = np.random.default_rng(5)
    x = jnp.asarray(rand_int8(rng, (130,)))
    w = jnp.asarray(rand_int8(rng, (130, 70)))
    a = np.asarray(pim_mvm(x, w, block_n=512))
    b = np.asarray(pim_mvm(x, w, block_n=16))
    np.testing.assert_array_equal(a, b)
