"""L2 model checks: shapes, KV-cache semantics, decode-vs-train
consistency, and quantized-path quality."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.model import (
    ToyConfig,
    decode_step,
    forward_train,
    generate_greedy,
    init_params,
    quantize_params,
    weight_names,
)

CFG = ToyConfig(d_model=64, layers=1, heads=2, max_seq=48, d_ffn=256)


@pytest.fixture(scope="module")
def weights():
    params = init_params(CFG, jax.random.PRNGKey(1))
    return quantize_params(params, CFG)


def test_weight_order_matches_names(weights):
    assert [n for n, _ in weights] == weight_names(CFG)


def test_decode_step_shapes(weights):
    arrays = [a for _, a in weights]
    kv = jnp.zeros((CFG.layers, 2, CFG.max_seq, CFG.d_model), jnp.float32)
    logits, kv2 = decode_step(
        CFG, jnp.asarray([65], jnp.int32), jnp.asarray([0], jnp.int32), kv, *arrays
    )
    assert logits.shape == (CFG.vocab,)
    assert kv2.shape == kv.shape


def test_kv_written_at_position(weights):
    arrays = [a for _, a in weights]
    kv = jnp.zeros((CFG.layers, 2, CFG.max_seq, CFG.d_model), jnp.float32)
    _, kv2 = decode_step(
        CFG, jnp.asarray([65], jnp.int32), jnp.asarray([3], jnp.int32), kv, *arrays
    )
    kv2 = np.asarray(kv2)
    # Row 3 written, everything else untouched (zero).
    assert np.any(kv2[:, :, 3, :] != 0)
    mask = np.ones(CFG.max_seq, bool)
    mask[3] = False
    assert np.all(kv2[:, :, mask, :] == 0)


def test_decode_deterministic(weights):
    arrays = [a for _, a in weights]
    kv = jnp.zeros((CFG.layers, 2, CFG.max_seq, CFG.d_model), jnp.float32)
    args = (CFG, jnp.asarray([7], jnp.int32), jnp.asarray([0], jnp.int32), kv)
    l1, _ = decode_step(*args, *arrays)
    l2, _ = decode_step(*args, *arrays)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_future_positions_masked(weights):
    # Garbage in future KV rows must not change the logits at pos 0.
    arrays = [a for _, a in weights]
    kv0 = jnp.zeros((CFG.layers, 2, CFG.max_seq, CFG.d_model), jnp.float32)
    kv_garbage = kv0.at[:, :, 10:, :].set(99.0)
    token = jnp.asarray([65], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    l_clean, _ = decode_step(CFG, token, pos, kv0, *arrays)
    l_dirty, _ = decode_step(CFG, token, pos, kv_garbage, *arrays)
    np.testing.assert_allclose(np.asarray(l_clean), np.asarray(l_dirty), rtol=1e-5, atol=1e-5)


def test_quantized_decode_tracks_float_model():
    # The W8A8+ADC decode path must rank tokens like the float model on a
    # trained network (top-1 agreement on a held-out snippet).
    from compile.train import train

    cfg = ToyConfig(d_model=64, layers=1, heads=2, max_seq=48, d_ffn=256)
    params, _ = train(cfg, steps=120, seed=0, batch=8, seq_len=32)
    weights = quantize_params(params, cfg)
    prompt = [ord(c) for c in "the flash array stores"]
    gen = generate_greedy(cfg, weights, prompt, 8)
    # Float model next-token for comparison.
    toks = jnp.asarray([prompt], jnp.int32)
    float_logits = forward_train(params, cfg, toks)[0, -1]
    float_next = int(jnp.argmax(float_logits))
    assert gen[0] == float_next, (gen[:4], float_next, bytes(gen).decode(errors='replace'))
