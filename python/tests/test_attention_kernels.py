"""dMVM RPU kernels (Fig. 13) vs exact integer oracles -- bit-exact."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.attention_pim import qk_ref, qk_vvm, sv_ref, sv_rowwise


def rand(rng, shape, lo=-128, hi=128):
    return rng.integers(lo, hi, size=shape).astype(np.int32)


@pytest.mark.parametrize("l,d", [(1, 8), (7, 16), (128, 128), (129, 64), (1000, 128)])
def test_qk_matches_ref(l, d):
    rng = np.random.default_rng(l * 31 + d)
    q = jnp.asarray(rand(rng, (d,)))
    k = jnp.asarray(rand(rng, (l, d)))
    np.testing.assert_array_equal(np.asarray(qk_vvm(q, k)), np.asarray(qk_ref(q, k)))


@pytest.mark.parametrize("l,d", [(1, 8), (7, 16), (128, 128), (257, 64)])
def test_sv_matches_ref(l, d):
    rng = np.random.default_rng(l * 37 + d)
    # Scores are INT16-ranged after softmax requantization.
    s = jnp.asarray(rand(rng, (l,), -256, 256))
    v = jnp.asarray(rand(rng, (l, d)))
    np.testing.assert_array_equal(np.asarray(sv_rowwise(s, v)), np.asarray(sv_ref(s, v)))


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=400),
    d=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_qk_hypothesis(l, d, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rand(rng, (d,)))
    k = jnp.asarray(rand(rng, (l, d)))
    np.testing.assert_array_equal(np.asarray(qk_vvm(q, k)), np.asarray(qk_ref(q, k)))


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(min_value=1, max_value=400),
    d=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_sv_hypothesis(l, d, seed):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rand(rng, (l,), -256, 256))
    v = jnp.asarray(rand(rng, (l, d)))
    np.testing.assert_array_equal(np.asarray(sv_rowwise(s, v)), np.asarray(sv_ref(s, v)))


def test_growing_context_is_prefix_consistent():
    # Scores for the first L rows must not change as the context grows
    # (the paper's append-only KV dataflow).
    rng = np.random.default_rng(3)
    d = 32
    q = jnp.asarray(rand(rng, (d,)))
    k_full = rand(rng, (300, d))
    small = np.asarray(qk_vvm(q, jnp.asarray(k_full[:200])))
    big = np.asarray(qk_vvm(q, jnp.asarray(k_full)))
    np.testing.assert_array_equal(small, big[:200])
