"""AOT export checks: HLO text integrity and manifest consistency."""

import os

import pytest

import jax
import jax.numpy as jnp

from compile.aot import lower_decode, to_hlo_text
from compile.model import ToyConfig, init_params, quantize_params


CFG = ToyConfig(d_model=64, layers=1, heads=2, max_seq=48, d_ffn=256)


@pytest.fixture(scope="module")
def hlo_text():
    params = init_params(CFG, jax.random.PRNGKey(0))
    weights = quantize_params(params, CFG)
    return lower_decode(CFG, weights)


def test_hlo_is_text_not_proto(hlo_text):
    assert hlo_text.startswith("HloModule")
    assert "ENTRY" in hlo_text


def test_no_mosaic_custom_calls(hlo_text):
    # interpret=True pallas must lower to plain HLO the CPU client runs.
    assert "custom-call" not in hlo_text


def test_parameter_count_matches_weights(hlo_text):
    params = init_params(CFG, jax.random.PRNGKey(0))
    weights = quantize_params(params, CFG)
    # token, pos, kv + weights
    expected = 3 + len(weights)
    import re
    entry = hlo_text[hlo_text.index("ENTRY") :]
    entry_block = entry[: entry.index("\n}")]
    nums = set(re.findall(r"parameter\((\d+)\)", entry_block))
    assert len(nums) == expected


def test_root_is_two_tuple(hlo_text):
    entry = hlo_text[hlo_text.index("ENTRY") :]
    assert "tuple(" in entry


def test_simple_fn_roundtrip():
    # The gen_hlo.py recipe works for arbitrary jitted functions.
    def fn(a, b):
        return (jnp.dot(a, b),)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
