"""Pure-jnp oracle of the 3D NAND flash PIM dot-product (paper SII-B).

This is the numeric ground truth the Pallas kernel must match **bit
exactly**. It models, in plain vectorized jnp:

* QLC nibble decomposition -- an 8-bit weight occupies two 4-bit cells on
  two bitlines (hi/lo nibble of the two's-complement byte);
* the 256-cell-per-bitline reliability limit -- row tiles of u = 128
  weights (2 cells each) accumulate independently;
* bit-serial activations -- 8 passes over the unsigned activation bits,
  recombined with +-2^b weights (bit 7 carries -2^7: two's complement);
* the 9-bit SAR ADC in the read path -- each analog bitline sum is
  floor-quantized to `adc_step` and clipped to `2^adc_bits - 1` codes
  (the 3D-FPIM "quantization-aware ADC");
* the digital sign-correction column (popcount of negative-weight rows,
  exact -- no ADC on the digital path).

`pim_mvm_ref(x, w) ~= x @ w` up to the documented ADC quantization error;
`adc_step=1` makes it exact for in-range sums.
"""

import jax.numpy as jnp

# Paper parameters.
ROWS_PER_TILE = 128  # u: 256 cells / 2 cells per weight
ADC_BITS = 9
ADC_STEP = 4
INPUT_BITS = 8


def adc(s: jnp.ndarray, adc_bits: int = ADC_BITS, adc_step: int = ADC_STEP) -> jnp.ndarray:
    """SAR ADC transfer function on a non-negative analog sum (int32)."""
    code = jnp.minimum(s // adc_step, (1 << adc_bits) - 1)
    return code * adc_step


def _pad_rows(x: jnp.ndarray, w: jnp.ndarray, u: int):
    m = x.shape[0]
    pad = (-m) % u
    if pad:
        x = jnp.pad(x, (0, pad))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return x, w


def pim_mvm_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    rows_per_tile: int = ROWS_PER_TILE,
    adc_bits: int = ADC_BITS,
    adc_step: int = ADC_STEP,
    input_bits: int = INPUT_BITS,
) -> jnp.ndarray:
    """PIM dot product: x int32[M] (int8 range) x w int32[M,N] -> int32[N]."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    x, w = _pad_rows(x, w, rows_per_tile)
    m = x.shape[0]
    n_tiles = m // rows_per_tile

    # Stored representation: unsigned byte -> nibbles; sign column.
    u_byte = jnp.where(w < 0, w + 256, w)
    hi = u_byte >> 4
    lo = u_byte & 0xF
    neg = (w < 0).astype(jnp.int32)
    xu = jnp.where(x < 0, x + 256, x)  # unsigned activation byte

    # [T, u, N] tiles / [T, u] activations.
    hi_t = hi.reshape(n_tiles, rows_per_tile, -1)
    lo_t = lo.reshape(n_tiles, rows_per_tile, -1)
    ng_t = neg.reshape(n_tiles, rows_per_tile, -1)
    xu_t = xu.reshape(n_tiles, rows_per_tile)

    out = jnp.zeros((w.shape[1],), dtype=jnp.int32)
    for b in range(input_bits):
        bit = (xu_t >> b) & 1  # [T, u]
        # Analog bitline sums per tile (<= u * 15 on the nibble BLs).
        s_hi = jnp.einsum("tu,tun->tn", bit, hi_t)
        s_lo = jnp.einsum("tu,tun->tn", bit, lo_t)
        s_ng = jnp.einsum("tu,tun->tn", bit, ng_t)  # digital, exact
        q = 16 * adc(s_hi, adc_bits, adc_step) + adc(s_lo, adc_bits, adc_step) - 256 * s_ng
        weight = -(1 << b) if b == input_bits - 1 else (1 << b)
        out = out + weight * jnp.sum(q, axis=0)
    return out


def exact_mvm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain integer matmul -- the no-ADC ideal."""
    return (x.astype(jnp.int32)[None, :] @ w.astype(jnp.int32))[0]


def adc_error_bound(m: int, input_bits: int = INPUT_BITS, adc_step: int = ADC_STEP) -> int:
    """Worst-case |pim_mvm_ref - exact_mvm| from ADC floor quantization.

    Each of the two nibble conversions loses < adc_step per (tile, bit);
    recombined as 16*hi + lo and summed over bit weights (2^0..2^7) and
    row tiles.
    """
    tiles = -(-m // ROWS_PER_TILE)
    per_bit = 17 * (adc_step - 1)  # 16*(step-1) + (step-1)
    bit_weight_sum = (1 << input_bits) - 1  # sum of 2^b magnitudes
    return tiles * per_bit * bit_weight_sum
