"""L1 Pallas kernel: the 3D NAND flash PIM sMVM array model.

One grid step emulates one *plane unit tile column group*: a block of
`block_n` output bitline pairs processing the whole input vector through
the bit-serial / nibble-decomposed / ADC-quantized dataflow of paper
SII-B (see `ref.py` for the numeric definition -- the kernel is bit-exact
against it).

Hardware adaptation (DESIGN.md SHardware-Adaptation): the paper's plane
tile is u x (N_col/4) = 128 x 512, so the kernel's BlockSpec uses a
128-row x 512-column tile -- the same HBM->VMEM schedule a TPU version
would use, with the MXU contraction running over the 128-row axis.

MUST run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_N = 512  # N_col / col_mux of the Size-A plane


def _kernel(x_ref, w_ref, o_ref, *, rows_per_tile, adc_bits, adc_step, input_bits):
    """One column block: full bit-serial PIM pipeline."""
    x = x_ref[...]  # [M] int32
    w = w_ref[...]  # [M, BN] int32
    m = x.shape[0]
    n_tiles = m // rows_per_tile

    u_byte = jnp.where(w < 0, w + 256, w)
    hi = (u_byte >> 4).reshape(n_tiles, rows_per_tile, -1)
    lo = (u_byte & 0xF).reshape(n_tiles, rows_per_tile, -1)
    ng = (w < 0).astype(jnp.int32).reshape(n_tiles, rows_per_tile, -1)
    xu = jnp.where(x < 0, x + 256, x).reshape(n_tiles, rows_per_tile)

    max_code = (1 << adc_bits) - 1

    def adc_q(s):
        return jnp.minimum(s // adc_step, max_code) * adc_step

    # SPerf: the bit-serial loop is vectorized over a leading bits axis
    # (one fused contraction instead of `input_bits` sequential passes);
    # integer adds are exact, so this is bit-identical to the serial
    # form the hardware executes.
    shifts = jnp.arange(input_bits, dtype=jnp.int32)
    bits = (xu[None, :, :] >> shifts[:, None, None]) & 1  # [B, T, u]
    s_hi = jnp.einsum("btu,tun->btn", bits, hi)
    s_lo = jnp.einsum("btu,tun->btn", bits, lo)
    s_ng = jnp.einsum("btu,tun->btn", bits, ng)
    q = 16 * adc_q(s_hi) + adc_q(s_lo) - 256 * s_ng  # [B, T, BN]
    # Two's complement: the MSB pass carries weight -2^(bits-1).
    weights = jnp.where(
        shifts == input_bits - 1, -(1 << shifts), 1 << shifts
    ).astype(jnp.int32)
    o_ref[...] = jnp.einsum("b,btn->n", weights, q.astype(jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("rows_per_tile", "adc_bits", "adc_step", "input_bits", "block_n"),
)
def pim_mvm(
    x,
    w,
    rows_per_tile: int = ref.ROWS_PER_TILE,
    adc_bits: int = ref.ADC_BITS,
    adc_step: int = ref.ADC_STEP,
    input_bits: int = ref.INPUT_BITS,
    block_n: int = DEFAULT_BLOCK_N,
):
    """PIM MVM: x int32[M] (int8 range) x w int32[M, N] -> int32[N]."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    m, n = w.shape

    # Pad rows to the tile size (extra rows are zero: no current flows).
    pad_m = (-m) % rows_per_tile
    if pad_m:
        x = jnp.pad(x, (0, pad_m))
        w = jnp.pad(w, ((0, pad_m), (0, 0)))
    # Pad cols to the block size.
    bn = min(block_n, n) if n >= 1 else 1
    pad_n = (-n) % bn
    if pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_n)))
    n_padded = n + pad_n
    m_padded = m + pad_m

    kernel = functools.partial(
        _kernel,
        rows_per_tile=rows_per_tile,
        adc_bits=adc_bits,
        adc_step=adc_step,
        input_bits=input_bits,
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_padded // bn,),
        in_specs=[
            pl.BlockSpec((m_padded,), lambda j: (0,)),
            pl.BlockSpec((m_padded, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n_padded,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)
    return out[:n]
