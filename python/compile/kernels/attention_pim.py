"""L1 Pallas kernels for the dynamic MVMs (paper Fig. 13): the RPU
datapath of the SLC region.

* `qk_vvm` -- QK^T as L vector-vector multiplies: q broadcast against
  the rows of the non-transposed K in the page buffers (Fig. 13a-c);
* `sv_rowwise` -- SV as the row-wise product: each score scales a row
  of V (vector-scalar multiply), partials accumulate down the H-tree
  (Fig. 13d-f).

Operands are INT8-valued (KV cache storage), arithmetic INT16xINT16 ->
INT32 exactly as the Table-I RPUs (8x INT16 multipliers, INT32 adders).
Both kernels are bit-exact against plain integer einsums -- the H-tree
ALU adds are exact INT32, so unlike the sMVM path there is no ADC term.

interpret=True always (CPU PJRT; see pim_mvm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qk_kernel(q_ref, k_ref, o_ref):
    """One grid step: a block of K rows against the broadcast q."""
    q = q_ref[...].astype(jnp.int32)      # [d]
    k = k_ref[...].astype(jnp.int32)      # [Lb, d]
    # RPU VVM: INT16 multiplies, INT32 accumulate.
    o_ref[...] = jnp.einsum("ld,d->l", k, q).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_l",))
def qk_vvm(q, k, block_l: int = 128):
    """q int32[d] (int8/int16 range) x K int32[L, d] -> scores int32[L]."""
    q = q.astype(jnp.int32)
    k = k.astype(jnp.int32)
    l, d = k.shape
    pad = (-l) % block_l
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0)))
    lp = l + pad
    out = pl.pallas_call(
        _qk_kernel,
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_l, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_l,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.int32),
        interpret=True,
    )(q, k)
    return out[:l]


def _sv_kernel(s_ref, v_ref, o_ref):
    """One grid step: a block of scores scales its V rows; the partial
    d-vectors accumulate (H-tree ALU mode) into the output."""
    s = s_ref[...].astype(jnp.int32)      # [Lb]
    v = v_ref[...].astype(jnp.int32)      # [Lb, d]
    partial = jnp.einsum("l,ld->d", s, v).astype(jnp.int32)
    # Accumulate across grid steps (sequential grid = running H-tree sum).
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pl.program_id(0) != 0)
    def _accum():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_l",))
def sv_rowwise(s, v, block_l: int = 128):
    """scores int32[L] x V int32[L, d] -> context int32[d] (row-wise)."""
    s = s.astype(jnp.int32)
    v = v.astype(jnp.int32)
    l, d = v.shape
    pad = (-l) % block_l
    if pad:
        s = jnp.pad(s, (0, pad))
        v = jnp.pad(v, ((0, pad), (0, 0)))
    lp = l + pad
    return pl.pallas_call(
        _sv_kernel,
        grid=(lp // block_l,),
        in_specs=[
            pl.BlockSpec((block_l,), lambda i: (i,)),
            pl.BlockSpec((block_l, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.int32),
        interpret=True,
    )(s, v)


def qk_ref(q, k):
    """Oracle: exact integer QK^T."""
    return jnp.einsum("ld,d->l", k.astype(jnp.int32), q.astype(jnp.int32))


def sv_ref(s, v):
    """Oracle: exact integer row-wise SV."""
    return jnp.einsum("l,ld->d", s.astype(jnp.int32), v.astype(jnp.int32))
