"""W8A8 quantization helpers (SmoothQuant-style, paper SIV-A).

Weights: symmetric per-output-channel int8. Activations: symmetric
per-tensor int8 with a dynamic (runtime) scale, as the flash controller
would compute from the page-buffer statistics. All quantized values are
carried as int32 (the Pallas kernel's arithmetic domain).
"""

import jax.numpy as jnp

INT8_MAX = 127


def weight_scales(w: jnp.ndarray) -> jnp.ndarray:
    """Per-output-column symmetric scale for a [M, N] weight matrix."""
    absmax = jnp.max(jnp.abs(w), axis=0)
    return jnp.maximum(absmax, 1e-8) / INT8_MAX


def quantize_weight(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8-valued int32 [M, N], per-column scale f32 [N])."""
    s = weight_scales(w)
    q = jnp.clip(jnp.round(w / s[None, :]), -INT8_MAX - 1, INT8_MAX)
    return q.astype(jnp.int32), s.astype(jnp.float32)


def act_scale(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor dynamic symmetric scale (scalar)."""
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / INT8_MAX


def quantize_act(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8-valued int32 [M], scalar scale f32)."""
    s = act_scale(x)
    q = jnp.clip(jnp.round(x / s), -INT8_MAX - 1, INT8_MAX)
    return q.astype(jnp.int32), s.astype(jnp.float32)


def dequantize(acc: jnp.ndarray, s_x: jnp.ndarray, s_w: jnp.ndarray) -> jnp.ndarray:
    """int32 accumulator [N] -> f32 via s_x * s_w[j]."""
    return acc.astype(jnp.float32) * s_x * s_w
