"""L2 JAX model: an OPT-style decoder ("OPT-toy") whose every linear
layer runs through the L1 Pallas PIM kernel (W8A8, paper Fig. 10
mapping). Two entry points:

* `forward_train` -- float, full-sequence, for the quick char-LM
  training run in `aot.py`;
* `decode_step` -- the quantized single-token path that is AOT-lowered
  to HLO text and served by the rust runtime (KV cache in/out, greedy
  sampling happens on the rust side).

Simplifications vs the paper's full system are documented in DESIGN.md:
softmax/LN stay f32 here (the controller runs them FP16), and the KV
cache is carried as f32 (the SLC region stores INT8; the simulator
models that storage, the functional path keeps full precision).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import quant
from .kernels.pim_mvm import pim_mvm


@dataclasses.dataclass(frozen=True)
class ToyConfig:
    vocab: int = 256
    d_model: int = 128
    layers: int = 2
    heads: int = 4
    max_seq: int = 160
    d_ffn: int = 512

    @property
    def d_head(self) -> int:
        return self.d_model // self.heads


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

LINEAR_NAMES = ["wq", "wk", "wv", "wo", "w1", "w2"]


def init_params(cfg: ToyConfig, key) -> dict:
    """Float training parameters."""
    keys = jax.random.split(key, 4 + cfg.layers * 8)
    k = iter(keys)
    scale = 0.02

    def dense(kk, m, n):
        return jax.random.normal(kk, (m, n), jnp.float32) * scale

    params = {
        "tok_emb": dense(next(k), cfg.vocab, cfg.d_model),
        "pos_emb": dense(next(k), cfg.max_seq, cfg.d_model),
        "ln_f_g": jnp.ones((cfg.d_model,), jnp.float32),
        "ln_f_b": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": dense(next(k), cfg.d_model, cfg.vocab),
    }
    for l in range(cfg.layers):
        params[f"l{l}_ln1_g"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[f"l{l}_ln1_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params[f"l{l}_wq"] = dense(next(k), cfg.d_model, cfg.d_model)
        params[f"l{l}_wk"] = dense(next(k), cfg.d_model, cfg.d_model)
        params[f"l{l}_wv"] = dense(next(k), cfg.d_model, cfg.d_model)
        params[f"l{l}_wo"] = dense(next(k), cfg.d_model, cfg.d_model)
        params[f"l{l}_ln2_g"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[f"l{l}_ln2_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params[f"l{l}_w1"] = dense(next(k), cfg.d_model, cfg.d_ffn)
        params[f"l{l}_w2"] = dense(next(k), cfg.d_ffn, cfg.d_model)
    return params


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# Float training forward (full sequence, causal)
# ---------------------------------------------------------------------------

def forward_train(params: dict, cfg: ToyConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens int32[B, T] -> logits f32[B, T, V]."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for l in range(cfg.layers):
        h = layer_norm(x, params[f"l{l}_ln1_g"], params[f"l{l}_ln1_b"])
        q = h @ params[f"l{l}_wq"]
        k = h @ params[f"l{l}_wk"]
        v = h @ params[f"l{l}_wv"]
        qh = q.reshape(b, t, cfg.heads, cfg.d_head)
        kh = k.reshape(b, t, cfg.heads, cfg.d_head)
        vh = v.reshape(b, t, cfg.heads, cfg.d_head)
        scores = jnp.einsum("bihd,bjhd->bhij", qh, kh) / jnp.sqrt(float(cfg.d_head))
        scores = jnp.where(mask[None, None], scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhij,bjhd->bihd", probs, vh).reshape(b, t, cfg.d_model)
        x = x + ctx @ params[f"l{l}_wo"]
        h = layer_norm(x, params[f"l{l}_ln2_g"], params[f"l{l}_ln2_b"])
        x = x + jax.nn.relu(h @ params[f"l{l}_w1"]) @ params[f"l{l}_w2"]
    x = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    return x @ params["lm_head"]


# ---------------------------------------------------------------------------
# Quantization: float params -> serving weight list (ordered)
# ---------------------------------------------------------------------------

def quantize_params(params: dict, cfg: ToyConfig) -> list[tuple[str, jnp.ndarray]]:
    """Ordered (name, array) list for the AOT decode graph.

    Quantized linears are exported as int8-valued f32 matrices plus
    per-column f32 scales (carried as f32 so every PJRT literal is f32).
    """
    out: list[tuple[str, jnp.ndarray]] = [
        ("tok_emb", params["tok_emb"]),
        ("pos_emb", params["pos_emb"]),
    ]
    for l in range(cfg.layers):
        out.append((f"l{l}_ln1_g", params[f"l{l}_ln1_g"]))
        out.append((f"l{l}_ln1_b", params[f"l{l}_ln1_b"]))
        for name in ["wq", "wk", "wv", "wo"]:
            q, s = quant.quantize_weight(params[f"l{l}_{name}"])
            out.append((f"l{l}_{name}_q", q.astype(jnp.float32)))
            out.append((f"l{l}_{name}_s", s))
        out.append((f"l{l}_ln2_g", params[f"l{l}_ln2_g"]))
        out.append((f"l{l}_ln2_b", params[f"l{l}_ln2_b"]))
        for name in ["w1", "w2"]:
            q, s = quant.quantize_weight(params[f"l{l}_{name}"])
            out.append((f"l{l}_{name}_q", q.astype(jnp.float32)))
            out.append((f"l{l}_{name}_s", s))
    out.append(("ln_f_g", params["ln_f_g"]))
    out.append(("ln_f_b", params["ln_f_b"]))
    q, s = quant.quantize_weight(params["lm_head"])
    out.append(("lm_head_q", q.astype(jnp.float32)))
    out.append(("lm_head_s", s))
    return out


def pim_linear(x: jnp.ndarray, w_q: jnp.ndarray, s_w: jnp.ndarray) -> jnp.ndarray:
    """Quantized linear through the Pallas PIM kernel (W8A8)."""
    xq, sx = quant.quantize_act(x)
    acc = pim_mvm(xq, w_q.astype(jnp.int32))
    return quant.dequantize(acc, sx, s_w)


# ---------------------------------------------------------------------------
# Serving decode step (lowered to HLO)
# ---------------------------------------------------------------------------

def decode_step(cfg: ToyConfig, token, pos, kv, *weights):
    """One token step.

    token i32[1], pos i32[1], kv f32[L, 2, S, D]
    -> (logits f32[V], kv' f32[L, 2, S, D])
    """
    w = dict(zip([n for n, _ in _weight_names_cache(cfg)], weights))
    t = token[0]
    p = pos[0]
    x = w["tok_emb"][t] + jax.lax.dynamic_index_in_dim(w["pos_emb"], p, 0, keepdims=False)

    s = cfg.max_seq
    positions = jnp.arange(s)
    for l in range(cfg.layers):
        h = layer_norm(x, w[f"l{l}_ln1_g"], w[f"l{l}_ln1_b"])
        q = pim_linear(h, w[f"l{l}_wq_q"], w[f"l{l}_wq_s"])
        k = pim_linear(h, w[f"l{l}_wk_q"], w[f"l{l}_wk_s"])
        v = pim_linear(h, w[f"l{l}_wv_q"], w[f"l{l}_wv_s"])
        # Append k, v to the cache at position p (SLC append path).
        kv = jax.lax.dynamic_update_slice(kv, k.reshape(1, 1, 1, -1), (l, 0, p, 0))
        kv = jax.lax.dynamic_update_slice(kv, v.reshape(1, 1, 1, -1), (l, 1, p, 0))
        keys = kv[l, 0].reshape(s, cfg.heads, cfg.d_head)
        vals = kv[l, 1].reshape(s, cfg.heads, cfg.d_head)
        qh = q.reshape(cfg.heads, cfg.d_head)
        # QK^T per head (RPU VVMs in the paper).
        scores = jnp.einsum("hd,jhd->hj", qh, keys) / jnp.sqrt(float(cfg.d_head))
        scores = jnp.where(positions[None, :] <= p, scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        # SV with the row-wise product dataflow.
        ctx = jnp.einsum("hj,jhd->hd", probs, vals).reshape(cfg.d_model)
        x = x + pim_linear(ctx, w[f"l{l}_wo_q"], w[f"l{l}_wo_s"])
        h = layer_norm(x, w[f"l{l}_ln2_g"], w[f"l{l}_ln2_b"])
        f = jax.nn.relu(pim_linear(h, w[f"l{l}_w1_q"], w[f"l{l}_w1_s"]))
        x = x + pim_linear(f, w[f"l{l}_w2_q"], w[f"l{l}_w2_s"])
    x = layer_norm(x, w["ln_f_g"], w["ln_f_b"])
    logits = pim_linear(x, w["lm_head_q"], w["lm_head_s"])
    return logits, kv


@functools.lru_cache(maxsize=8)
def _weight_names_cache(cfg: ToyConfig) -> tuple:
    """Weight name order without materializing arrays."""
    names = [("tok_emb", None), ("pos_emb", None)]
    for l in range(cfg.layers):
        names.append((f"l{l}_ln1_g", None))
        names.append((f"l{l}_ln1_b", None))
        for name in ["wq", "wk", "wv", "wo"]:
            names.append((f"l{l}_{name}_q", None))
            names.append((f"l{l}_{name}_s", None))
        names.append((f"l{l}_ln2_g", None))
        names.append((f"l{l}_ln2_b", None))
        for name in ["w1", "w2"]:
            names.append((f"l{l}_{name}_q", None))
            names.append((f"l{l}_{name}_s", None))
    names.append(("ln_f_g", None))
    names.append(("ln_f_b", None))
    names.append(("lm_head_q", None))
    names.append(("lm_head_s", None))
    return tuple(names)


def weight_names(cfg: ToyConfig) -> list[str]:
    return [n for n, _ in _weight_names_cache(cfg)]


# ---------------------------------------------------------------------------
# Reference decode loop (python-side greedy generation, for tests)
# ---------------------------------------------------------------------------

def generate_greedy(cfg: ToyConfig, weights: list, prompt: list[int], max_new: int):
    """Greedy generation mirroring the rust serving loop."""
    kv = jnp.zeros((cfg.layers, 2, cfg.max_seq, cfg.d_model), jnp.float32)
    arrays = [a for _, a in weights]
    logits = None
    pos = 0
    for t in prompt:
        logits, kv = decode_step(
            cfg, jnp.asarray([t], jnp.int32), jnp.asarray([pos], jnp.int32), kv, *arrays
        )
        pos += 1
    out = []
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits))
        out.append(nxt)
        if pos >= cfg.max_seq:
            break
        logits, kv = decode_step(
            cfg, jnp.asarray([nxt], jnp.int32), jnp.asarray([pos], jnp.int32), kv, *arrays
        )
        pos += 1
    return out
