"""Quick char-LM training for the OPT-toy (build-time only).

A synthetic corpus with enough structure to show a falling loss curve
and produce recognizable continuations; hand-rolled Adam. The loss log
is exported next to the artifacts and recorded in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .model import ToyConfig, forward_train, init_params

CORPUS = (
    "the flash array stores the model weights in qlc cells. "
    "the h tree adds partial sums on the way out. "
    "the slc region keeps the kv cache close to the rpus. "
    "token generation streams bits over the wordlines. "
    "the controller runs softmax on its arm cores. "
    "a plane reads a page through the bitlines. "
) * 64


def batches(seq_len: int, batch: int, seed: int):
    data = np.frombuffer(CORPUS.encode(), dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, len(data) - seq_len - 1, size=batch)
        x = np.stack([data[i : i + seq_len] for i in idx])
        y = np.stack([data[i + 1 : i + seq_len + 1] for i in idx])
        yield jnp.asarray(x), jnp.asarray(y)


def loss_fn(params, cfg, x, y):
    logits = forward_train(params, cfg, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: ToyConfig, steps: int = 200, seed: int = 0, batch: int = 16, seq_len: int = 64):
    """Returns (params, loss_log: list[(step, loss)])."""
    params = init_params(cfg, jax.random.PRNGKey(seed))
    state = adam_init(params)
    gen = batches(seq_len, batch, seed)

    @jax.jit
    def step_fn(params, state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, x, y)
        params, state = adam_update(params, grads, state)
        return params, state, loss

    log = []
    for step in range(steps):
        x, y = next(gen)
        params, state, loss = step_fn(params, state, x, y)
        if step % 10 == 0 or step == steps - 1:
            log.append((step, float(loss)))
    return params, log
