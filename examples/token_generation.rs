//! END-TO-END driver: serve real generation requests through the full
//! three-layer stack and report latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example token_generation
//! ```
//!
//! * **functional path** — the rust coordinator loads the AOT-compiled
//!   JAX/Pallas decode step (HLO text → PJRT CPU) for the trained
//!   OPT-toy char-LM and generates actual tokens, batch of requests,
//!   single-batch device semantics;
//! * **timing path** — the same token counts run through the flash-PIM
//!   timing simulator at OPT-30B scale, reporting the simulated TPOT the
//!   paper's Fig. 14 claims.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::serve::{Coordinator, Job};
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::runtime::{ArtifactBundle, ByteTokenizer, DecodeExecutor};
use flashpim::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactBundle::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // The serving coordinator owns the PJRT executor on its worker thread.
    let dir2 = dir.clone();
    let coord = Coordinator::new(move || {
        DecodeExecutor::load(&dir2).expect("artifacts load cleanly")
    });
    let tok = ByteTokenizer;

    let prompts = [
        "the flash ",
        "the h tree ",
        "the slc region ",
        "token generation ",
        "a plane reads ",
        "the controller ",
    ];
    let max_new = 48;

    println!("== functional serving over the PJRT runtime ==");
    let mut walls = Vec::new();
    let mut ttfts = Vec::new();
    let mut total_tokens = 0usize;
    let t0 = std::time::Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        let served = coord.run(Job { id: i as u64, prompt: tok.encode(p), max_new })?;
        println!("  [{}] {:?} -> {:?}", served.id, p, tok.decode(&served.tokens));
        walls.push(served.wall);
        ttfts.push(served.ttft);
        total_tokens += served.tokens.len();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let lat = Summary::of(&walls);
    let ttft = Summary::of(&ttfts);
    println!(
        "served {} requests / {} tokens in {:.2}s  ({:.1} tok/s)",
        prompts.len(),
        total_tokens,
        elapsed,
        total_tokens as f64 / elapsed
    );
    println!(
        "request latency mean {:.3}s p99 {:.3}s   TTFT mean {:.3}s",
        lat.mean, lat.p99, ttft.mean
    );

    println!();
    println!("== simulated flash-PIM timing at OPT-30B scale ==");
    let sys = table1_system();
    let table = LatencyTable::build(&sys, &TechParams::default(), OptModel::Opt30b.shape());
    let l_in = 1024;
    let sim = table.decode_time(l_in, total_tokens);
    let tpot = sim.secs() / total_tokens as f64;
    println!(
        "generating the same {} tokens at OPT-30B scale on the flash device: {} (TPOT {})",
        total_tokens,
        sim,
        flashpim::util::units::fmt_time(tpot)
    );
    let gpu = flashpim::gpu::rtx4090x4_vllm();
    if let Some(g) = gpu.tpot(&OptModel::Opt30b.shape(), 1.0, l_in) {
        println!("4xRTX4090 (vLLM) TPOT at the same point: {} → speedup {:.2}x",
            flashpim::util::units::fmt_time(g), g / tpot);
    }
    Ok(())
}
