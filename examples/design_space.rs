//! Design-space exploration walkthrough (paper §III-B, Fig. 6): sweep
//! the plane configuration, print the latency/energy/density series, the
//! Pareto frontier, and the selected plane.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use flashpim::circuit::TechParams;
use flashpim::dse::pareto::pareto_frontier;
use flashpim::dse::select::{select_plane, SelectionCriteria};
use flashpim::dse::sweep::sweep_grid;
use flashpim::util::table::Table;
use flashpim::util::units::{fmt_energy, fmt_time};

fn main() {
    let tech = TechParams::default();

    // Fig. 6: the three 1-D sweeps.
    print!("{}", flashpim::exp::fig6::render());

    // The full 3-D grid and its latency/density Pareto frontier.
    let grid = sweep_grid((64, 2048), (256, 16384), (32, 512), &tech);
    println!("full grid: {} configurations", grid.len());
    let frontier = pareto_frontier(&grid);
    let mut t = Table::new(&["plane (r×c×s)", "T_PIM", "energy", "Gb/mm²"]);
    for p in &frontier {
        t.row(&[
            format!("{}x{}x{}", p.plane.n_row, p.plane.n_col, p.plane.n_stack),
            fmt_time(p.t_pim),
            fmt_energy(p.energy),
            format!("{:.2}", p.density),
        ]);
    }
    println!("latency/density Pareto frontier ({} points):", frontier.len());
    t.print();

    // Budget sensitivity: what would other latency budgets select?
    println!();
    println!("selection vs latency budget:");
    for budget_us in [1.0, 1.5, 2.0, 3.0, 5.0] {
        let crit = SelectionCriteria {
            max_t_pim: budget_us * 1e-6,
            ..SelectionCriteria::default()
        };
        match select_plane(&crit, &tech) {
            Some((w, feas)) => println!(
                "  {budget_us:>4.1} µs → {}x{}x{}  ({:.2} Gb/mm², {} feasible)",
                w.plane.n_row, w.plane.n_col, w.plane.n_stack, w.density, feas.len()
            ),
            None => println!("  {budget_us:>4.1} µs → infeasible"),
        }
    }
}
