//! Serving-offload study (paper §I deployment argument): a mixed
//! summarization + generation trace through the coordinator's router —
//! generation offloads to the flash PIM device, summarization stays on
//! the GPU pool — versus running everything on the GPUs.
//!
//! ```bash
//! cargo run --release --example serving_offload
//! ```

use flashpim::config::presets::table1_system;
use flashpim::coordinator::{simulate, Workload};
use flashpim::gpu::rtx4090x4_vllm;
use flashpim::llm::model_config::OptModel;
use flashpim::util::table::Table;

fn main() {
    let sys = table1_system();
    let model = OptModel::Opt13b.shape();
    let gpu = rtx4090x4_vllm();

    println!("workload: 48 requests, OPT-13B, 256-token prompts, 64-token generations\n");

    let mut t = Table::new(&[
        "gen fraction",
        "flash reqs",
        "gpu reqs",
        "mean latency",
        "p99 latency",
        "tok/s",
        "util flash",
        "util gpu",
    ]);
    for gen_frac in [0.25, 0.5, 0.75, 0.9] {
        let wl = Workload::synthetic(48, gen_frac, 0.4, 256, 64, 7);
        let rep = simulate(&sys, &model, &gpu, &wl);
        let lat = rep.latency_summary();
        let (flash, gpu_n) = rep.counts();
        t.row(&[
            format!("{:.0}%", gen_frac * 100.0),
            flash.to_string(),
            gpu_n.to_string(),
            flashpim::util::units::fmt_time(lat.mean),
            flashpim::util::units::fmt_time(lat.p99),
            format!("{:.1}", rep.throughput()),
            format!("{:.0}%", rep.flash_utilization * 100.0),
            format!("{:.0}%", rep.gpu_utilization * 100.0),
        ]);
    }
    t.print();

    println!();
    println!("The GPUs spend their time on prefill only — the flash device");
    println!("absorbs the bandwidth-bound generation stage (paper Fig. 1b/5).");
}
