//! Quickstart: the library in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the core API: load the Table-I system, evaluate the circuit
//! model on the selected plane, run the design-space selection, execute
//! one sMVM through the H-tree pipeline, and search the best tiling.

use flashpim::circuit::{cell_density_gb_mm2, PlaneLatency, TechParams};
use flashpim::config::presets::table1_system;
use flashpim::dse::select::{select_plane, SelectionCriteria};
use flashpim::nand::NandTiming;
use flashpim::pim::op::MvmShape;
use flashpim::pim::smvm::SmvmPipeline;
use flashpim::tiling::{search_best, TilingCostModel};
use flashpim::util::units::{fmt_energy, fmt_time};

fn main() -> anyhow::Result<()> {
    // 1. The Table-I system configuration (paper §V-A).
    let sys = table1_system();
    let tech = TechParams::default();
    println!("system: {} — {} channels × {} ways × {} dies × {} planes",
        sys.name, sys.org.channels, sys.org.ways_per_channel,
        sys.org.dies_per_way, sys.org.planes_per_die);

    // 2. Circuit model of the selected Size-A plane.
    let lat = PlaneLatency::of(&sys.plane, &tech);
    println!(
        "plane {}x{}x{}: T_PIM(8b) = {}  (decWL {} + 8 × cycle {})",
        sys.plane.n_row, sys.plane.n_col, sys.plane.n_stack,
        fmt_time(lat.t_pim(8)),
        fmt_time(lat.t_decwl),
        fmt_time(lat.pim_cycle()),
    );
    println!("cell density: {:.2} Gb/mm²", cell_density_gb_mm2(&sys.plane, &tech));

    // 3. Design-space selection (paper §III-B): re-derive Size A.
    let (winner, feasible) = select_plane(&SelectionCriteria::default(), &tech).unwrap();
    println!(
        "DSE: {} feasible configs under 2 µs; densest = {}x{}x{} at {:.2} Gb/mm²",
        feasible.len(), winner.plane.n_row, winner.plane.n_col, winner.plane.n_stack, winner.density
    );

    // 4. One sMVM through the H-tree pipeline (paper Fig. 9 machinery).
    let timing = NandTiming::of_system(&sys, &tech);
    let pipe = SmvmPipeline::new(&sys, timing.clone(), 64);
    let rep = pipe.execute(MvmShape::new(4096, 4096));
    println!(
        "sMVM (4K×4K) on 64 planes: inbound {}  pim {}  total {}",
        rep.inbound_done, rep.pim_done, rep.total
    );
    let e = flashpim::circuit::PimEnergy::of(&sys.plane, &tech, 128, 0.5);
    println!("per-op energy: {}", fmt_energy(e.total_op(8)));

    // 5. Best tiling for the OPT-30B projection (paper Fig. 12).
    let model = TilingCostModel::new(&sys, timing);
    let best = &search_best(&model, MvmShape::new(7168, 7168))[0];
    println!(
        "best tiling for d_m=7168: {} → total {}",
        best.scheme.notation_counts(),
        fmt_time(best.cost.total().secs())
    );
    Ok(())
}
