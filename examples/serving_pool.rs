//! Device-pool serving study: closed-loop Poisson traffic against a pool
//! of flash-PIM devices, comparing scheduler policies and pool sizes at
//! the same offered load, then sweeping arrival rates into a
//! throughput–latency curve (the paper's vLLM-comparison shape).
//!
//! ```bash
//! cargo run --release --example serving_pool
//! ```
//!
//! Everything below runs on the deterministic event-driven simulator
//! (`coordinator::event_sim`): a single thread replays the whole trace
//! as discrete events, per-request device time comes from one
//! precomputed `LatencyTable`, the prefill path prices the PCIe KV
//! upload, and re-running this example reproduces every number bit for
//! bit. (`serve-sim --threaded` keeps the legacy direct-replay backend
//! around as a cross-check.)

use flashpim::circuit::TechParams;
use flashpim::config::presets::table1_system;
use flashpim::coordinator::{
    FleetSpec, policy_from_name, render_slo_frontier, render_sweep, run_traffic_events,
    sweep_rates, TIERED_POLICY_NAMES, TrafficConfig, WorkloadMix,
};
use flashpim::llm::LatencyTable;
use flashpim::llm::model_config::OptModel;
use flashpim::util::table::Table;
use flashpim::util::units::fmt_time;

fn main() {
    let sys = table1_system();
    let model = OptModel::Opt6_7b.shape();
    // One offline build; every run below queries it immutably.
    let table = LatencyTable::build(&sys, &TechParams::default(), model.clone());
    let mut cfg = TrafficConfig::default_for(1);
    cfg.rate = 12.0;
    cfg.requests = 250;

    println!(
        "workload: {} Poisson arrivals at {:.0} req/s, {}, prompts {}-{}, outputs {}-{}",
        cfg.requests,
        cfg.rate,
        model.name,
        cfg.input_tokens.lo,
        cfg.input_tokens.hi,
        cfg.output_tokens.lo,
        cfg.output_tokens.hi,
    );
    println!(
        "latency table: {} buckets of {} tokens, built once and shared\n",
        table.max_context() / table.stride() + 1,
        table.stride(),
    );

    let mut t = Table::new(&[
        "pool",
        "policy",
        "accepted",
        "rejected",
        "TTFT p95",
        "latency p50",
        "latency p95",
        "latency p99",
        "tok/s",
        "max util",
    ]);
    for devices in [1, 2, 4, 8] {
        for policy_name in ["round-robin", "least-loaded"] {
            let policy = policy_from_name(policy_name).expect("known policy");
            cfg.devices = devices;
            let rep = run_traffic_events(&sys, &model, &table, policy, &cfg);
            let lat = rep.latency_summary();
            let max_util =
                rep.device_utilization.iter().cloned().fold(0.0f64, f64::max);
            t.row(&[
                format!("{devices} dev"),
                policy_name.to_string(),
                rep.accepted().to_string(),
                rep.rejected().to_string(),
                fmt_time(rep.ttft_summary().p95),
                fmt_time(lat.p50),
                fmt_time(lat.p95),
                fmt_time(lat.p99),
                format!("{:.1}", rep.throughput()),
                format!("{:.0}%", max_util * 100.0),
            ]);
        }
    }
    t.print();

    println!();
    println!("A single device saturates at this arrival rate; the pool absorbs it.");
    println!("Least-loaded beats round-robin at the tail because it never queues");
    println!("behind a long generation when a sibling device sits idle.");
    println!();
    println!("Throughput-latency curve, 4 devices, both policies (one deterministic");
    println!("event timeline per point, all points sharing one latency table):");
    println!();
    cfg.devices = 4;
    let rates = [4.0, 8.0, 16.0, 24.0, 32.0];
    let points =
        sweep_rates(&sys, &model, &table, &cfg, &rates, &["round-robin", "least-loaded"])
            .expect("valid sweep");
    print!("{}", render_sweep(&points));

    println!();
    println!("Full per-run report for the 4-device least-loaded configuration:");
    println!();
    let rep = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("least-loaded").unwrap(),
        &cfg,
    );
    print!("{}", rep.render());

    println!();
    println!("Multi-class scenario: the `summarize-long` preset blends interactive");
    println!("chat (150 ms TTFT target) with 1K+-token summarization prefills.");
    println!("Per-class percentiles and SLO attainment, SLO-aware scheduling:");
    println!();
    cfg.workload = Some(WorkloadMix::preset("summarize-long").expect("built-in preset"));
    cfg.rate = 10.0;
    let rep = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("slo-aware").unwrap(),
        &cfg,
    );
    print!("{}", rep.render());

    println!();
    println!("Sweeping the mix over arrival rates reduces to the SLO frontier —");
    println!("the max offered rate each class sustains at >=99% attainment:");
    println!();
    let points = sweep_rates(
        &sys,
        &model,
        &table,
        &cfg,
        &[4.0, 8.0, 12.0, 16.0],
        &["round-robin", "least-loaded", "slo-aware"],
    )
    .expect("valid sweep");
    print!("{}", render_slo_frontier(&points, 0.99));

    println!();
    println!("Hybrid fleet: 4 flash-PIM cards + 1 tensor-parallel GPU node on the");
    println!("same mix. The tier-aware policy routes long summarization prefills");
    println!("to the GPU tier and keeps short chat turns on flash; the report");
    println!("gains a per-tier utilization table plus fleet $/Mtok and J/Mtok:");
    println!();
    let fleet = FleetSpec::parse("4xflash+1xgpu").expect("valid fleet spec");
    cfg.devices = fleet.n_devices();
    cfg.fleet = Some(fleet);
    let rep = run_traffic_events(
        &sys,
        &model,
        &table,
        policy_from_name("tier-aware").unwrap(),
        &cfg,
    );
    print!("{}", rep.render());

    println!();
    println!("The same fleet swept across rates prices every point — the sweep");
    println!("table grows $/Mtok and J/Mtok columns, and tier-aware joins the");
    println!("policy roster:");
    println!();
    let points = sweep_rates(&sys, &model, &table, &cfg, &[4.0, 8.0, 12.0], TIERED_POLICY_NAMES)
        .expect("valid sweep");
    print!("{}", render_sweep(&points));
}
