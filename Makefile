# Local targets mirroring the CI jobs (.github/workflows/ci.yml) exactly,
# so a green `make ci` means a green pipeline.

.PHONY: build test fmt clippy lint bench-check bench-json campaign campaign-update-baseline \
	perf-smoke doc doc-test check-docs-links ci

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

lint: fmt clippy

bench-check:
	cargo bench --no-run --workspace

# Machine-readable serving-perf metrics (events/s, requests/s, sweep
# wall-clock). CI runs the campaign on a reduced budget (BENCH_ITERS /
# BENCH_REQUESTS / BENCH_SWEEP_REQUESTS env knobs) and uploads the JSON.
# Override the output path with `make bench-json BENCH_JSON=/tmp/b.json`;
# the default is absolute because cargo runs bench binaries with cwd =
# the package root (rust/), not the workspace root.
BENCH_JSON ?= $(CURDIR)/BENCH_serving.json
bench-json:
	cargo bench --bench perf_hotpath -- --json $(BENCH_JSON)

# Scenario campaign (policies x workload presets x backends x rate grid),
# gated against the committed baseline — the exact invocation CI's
# campaign-gate job runs. Deterministic: fixed seed, canonical ordering.
# Filter with `make campaign CAMPAIGN_FLAGS="--filter 'class(chat)'"`.
campaign:
	cargo run --release --bin repro -- campaign --out $(BENCH_JSON) $(CAMPAIGN_FLAGS)

# Refresh bench/BENCH_serving.baseline.json from a full deterministic
# run (review the diff before committing; see docs/CAMPAIGNS.md).
# Honours the same CAMPAIGN_FLAGS passthrough as `make campaign` so a
# fleet axis (`--fleets ...`) lands in the gate and the baseline alike.
campaign-update-baseline:
	cargo run --release --bin repro -- campaign --update-baseline $(CAMPAIGN_FLAGS)

# 1M-request bit-identity smoke test (ignored by default in `make test`).
perf-smoke:
	cargo test --release --test perf_equivalence -- --ignored --nocapture

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

doc-test:
	cargo test --doc --workspace

check-docs-links:
	python3 scripts/check_docs_links.py

ci: build test lint bench-check doc doc-test check-docs-links
