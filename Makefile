# Local targets mirroring the CI jobs (.github/workflows/ci.yml) exactly,
# so a green `make ci` means a green pipeline.

.PHONY: build test fmt clippy lint bench-check bench-json perf-smoke doc doc-test check-docs-links ci

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

lint: fmt clippy

bench-check:
	cargo bench --no-run --workspace

# Machine-readable serving-perf metrics (events/s, requests/s, sweep
# wall-clock). CI runs this on a reduced budget (BENCH_ITERS /
# BENCH_REQUESTS / BENCH_SWEEP_REQUESTS env knobs) and uploads the JSON.
# Absolute path: cargo runs bench binaries with cwd = the package root
# (rust/), not the workspace root.
bench-json:
	cargo bench --bench perf_hotpath -- --json $(CURDIR)/BENCH_serving.json

# 1M-request bit-identity smoke test (ignored by default in `make test`).
perf-smoke:
	cargo test --release --test perf_equivalence -- --ignored --nocapture

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

doc-test:
	cargo test --doc --workspace

check-docs-links:
	python3 scripts/check_docs_links.py

ci: build test lint bench-check doc doc-test check-docs-links
