# Local targets mirroring the CI jobs (.github/workflows/ci.yml) exactly,
# so a green `make ci` means a green pipeline.

.PHONY: build test fmt clippy lint bench-check doc doc-test check-docs-links ci

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

lint: fmt clippy

bench-check:
	cargo bench --no-run --workspace

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace

doc-test:
	cargo test --doc --workspace

check-docs-links:
	python3 scripts/check_docs_links.py

ci: build test lint bench-check doc doc-test check-docs-links
